//! Subgraph-level KV cache (the paper §3.4), grown from the seed's
//! single-resident slot into a process-wide, thread-safe, **tiered** pool
//! shared across concurrent query streams.
//!
//! # Architecture
//!
//! Two layers:
//!
//! * [`SharedKvCache`] — the `Send + Sync` pool. One per process (or per
//!   backend): a byte/entry-budgeted LRU over representative KV caches,
//!   keyed by **representative content hash** ([`RepKey`]) so identical
//!   representatives resident in two streams share ONE entry — the paper's
//!   intra-stream reuse extended to inter-stream reuse (the same
//!   deduplication insight prompt-cache systems exploit). The index is
//!   **sharded by key** (`CachePolicy::shards` shards, each its own mutex +
//!   condvar + [`LockStats`]), so tier-copy bookkeeping done under a lock
//!   never serializes unrelated keys at high stream counts.
//! * [`KvCacheManager`] — a thin **per-stream view** over a pool. Each
//!   serving stream owns one view; the view carries the stream's own
//!   hit/miss accounting ([`CacheStats`]), its cluster-id → content-key
//!   bindings, and the pins it holds. [`KvCacheManager::new`] wraps a
//!   private pool (exactly the PR 3 single-stream behaviour);
//!   [`KvCacheManager::shared_view`] attaches to a shared one.
//!
//! # Tier lifecycle: resident → host → disk → dead
//!
//! With `CachePolicy::host_bytes > 0` the pool is two-tiered, and with
//! `CachePolicy::disk_bytes > 0` on top a third, disk-backed tier sits
//! under the host tier. A device entry's KV is no longer destroyed by
//! eviction — it is **demoted**, and a host copy falling out of the host
//! budget is **archived** instead of dying:
//!
//! * **resident** — the entry lives on the device, pinnable, LRU-tracked.
//! * **host** — budget eviction hands the caller a [`Demotion`] work item
//!   (`{ handle, slot }`) instead of a bare release handle. The caller
//!   copies the KV off-device (`Backend::demote_kv`) and gives the host
//!   handle back via [`KvCacheManager::admit_host`]. Host entries are never
//!   pinned and never satisfy a device read; they exist to be promoted.
//! * **disk** — with the disk tier enabled, a host-budget LRU death leaves
//!   as an [`Archival`] work item instead: the caller serializes the KV
//!   (`Backend::archive_kv` consumes the host handle) and hands the bytes
//!   back via [`KvCacheManager::admit_disk`], which appends a framed
//!   record to the pool's archive file. Archived records are bytes, not
//!   backend handles — they survive lane deaths by construction and cost
//!   nothing on the device.
//! * **dead** — with the disk tier off, the host tier's LRU
//!   *demotion-to-death* applies: admitting a host copy over
//!   `CachePolicy::host_bytes` returns the coldest host handles for
//!   release. The disk tier's own byte budget kills the coldest records
//!   outright (there is nowhere further to spill). Death is also where any
//!   tier copy goes when a fresh install supersedes it (the tiers never
//!   hold two live copies of one key) or when a checkout is abandoned.
//!
//! A lookup that finds a host copy returns [`Lookup::MustPromote`]: the
//! host handle is **checked out** of the pool (single-flight — the key is
//! reserved exactly as a `MustInstall` miss reserves it, so racing streams
//! block and then hit the promoted entry), the caller copies it back up
//! (`Backend::promote_kv`) and completes with
//! [`KvCacheManager::install_promoted`]. The serving scheduler overlaps
//! that copy in the **ticket shadow** — the promote ticket is submitted,
//! pipeline prep for the next query runs while the copy is in flight, and
//! only then is the ticket waited — so a promotion charges the caller the
//! copy latency minus the shadowed work, strictly less than the repaid
//! prefill it replaces. A host hit counts as a `miss` *plus* a `host_hit`
//! (the caller still pays a copy), and the completed copy-up counts as a
//! `promotion`, not a `prefill`.
//!
//! A lookup that finds an archived record returns [`Lookup::MustRecall`]
//! under the same contract: the record is checked out (read from disk,
//! checksum-verified, and consumed), the key is reserved, and the caller
//! walks the bytes disk → host → device (`Backend::recall_kv` rebuilds a
//! host copy, the normal promote path uploads it) before completing with
//! [`KvCacheManager::install_recalled`]. A disk hit counts as a `miss`
//! plus a `disk_hit`, and the completed walk counts as a `recall`.
//!
//! # Archive framing & compaction
//!
//! The archive is a single append-only file (created lazily in the OS
//! temp dir, deleted with the pool). Each record is framed
//! `[key u64][kv_bytes u64][len u32][checksum u64]` (little-endian)
//! followed by `len` payload bytes; the checksum is FNV-1a over the
//! payload. A checkout re-reads the payload and verifies length and
//! checksum — a truncated or torn record (crash-partial write, external
//! corruption) is **treated as a miss**: the record is dropped, the
//! lookup falls through to `MustInstall`, and the caller repays the
//! prefill. Never a panic, never a poisoned pool. Dead records (consumed
//! checkouts, superseded or budget-killed keys) leave their payload bytes
//! in the file until **compaction**: when dead payload bytes exceed live
//! payload bytes, the live records are rewritten to a fresh file which
//! atomically replaces the old one. Serialization is the backend's
//! business (`Backend::archive_kv`/`recall_kv`); the pool stores opaque
//! bytes.
//!
//! # Sharded-index locking rules
//!
//! * Every key lives in exactly one shard (`key % shards`); single-key
//!   operations (lookup, install, pin/unpin, release) lock only that
//!   shard's mutex. Install-reservation waiters block on that shard's
//!   condvar.
//! * Pool-global residency (`resident_bytes`, `peak_bytes`, `host_bytes`,
//!   entry count) lives in atomics that are only mutated while holding the
//!   owning shard's lock.
//! * Cross-shard passes — budget eviction, host-budget enforcement,
//!   [`drain_all`](SharedKvCache::drain_all), [`budget_ok`], [`consistent`]
//!   — lock **all shards in ascending index order** (the deadlock-freedom
//!   rule), so they observe a true snapshot: no mutator can be mid-update,
//!   because every mutation happens under some shard lock.
//! * The deferred-release graveyard is a single pool-level list locked
//!   *after* any shard locks (shards → graveyard, never the reverse).
//! * `install` admits its entry under the key's shard lock, **releases
//!   it**, and only then runs the global eviction pass under all locks.
//!   Concurrent installs may interleave here; each pass evicts to budget,
//!   so whichever pass runs last restores the install-point invariant —
//!   the just-installed entry is pinned and thus never a victim.
//!
//! # The sharing / pinning / eviction contract
//!
//! * **Keys.** A shared view [`bind`]s each of its clusters to a [`RepKey`]
//!   (content hash of backbone + graph + representative subgraph). Two
//!   streams that bind the same key address the same pool entry. Unbound
//!   clusters (and every cluster of a private view) get a view-salted key,
//!   reproducing PR 3's per-stream-private entries exactly.
//! * **Single-flight installs.** A [`lookup`] miss *reserves* the key: the
//!   caller must [`install`] (or [`abort_install`]) it. Another stream that
//!   looks up a reserved key **blocks** until the reservation resolves,
//!   then hits the freshly installed entry — so N streams racing on one
//!   representative pay exactly one prefill (or one promotion), never N. A
//!   view dropped with reservations outstanding (serve path unwound on
//!   error) aborts them, so waiters never hang on a dead installer: they
//!   wake, re-reserve, and surface their own error.
//! * **Pins are global.** An entry's pin count sums every stream's pins.
//!   [`lookup`] hits and [`install`]s return with the caller holding one
//!   pin; pins nest; a view can only unpin pins it holds. Eviction (LRU,
//!   at install under budget pressure) only ever removes entries with
//!   **zero pins across all streams** — if pinned entries alone exceed the
//!   budget the pool runs over budget rather than corrupting another
//!   stream's in-flight extend.
//! * **Deferred release.** An explicit [`release`] of an entry another
//!   stream still pins does not return its handle: the entry is marked
//!   *doomed* and the handle moves to a graveyard when the last pin drops.
//!   Every handle-returning call drains the graveyard, so deferred handles
//!   reach the backend at the next natural release point. A lookup hit (or
//!   a racing re-install) of a doomed entry resurrects it — it is
//!   demonstrably still hot. TTL sweeps use [`expire`] instead: a private
//!   view releases now, a shared view only drops its own binding (one
//!   stream's staleness must not reclaim the fleet's warm entry).
//! * **Quarantine.** When a lane worker dies and restarts, device KV state
//!   minted by the dead incarnation is gone even though the pool still
//!   lists its handles. [`quarantine_stale`] sweeps the **device tier**
//!   with a caller-supplied staleness predicate (in serving:
//!   `!backend.kv_current(h)`), removing every stale entry — **pinned or
//!   not**, since pins protect live device reads and a dead incarnation
//!   has none left to protect — and returning the dead handles for
//!   bookkeeping release. **Host-tier copies and archived disk records
//!   are never swept**: neither dies with a device lane, so after a
//!   quarantine the next lookup finds the surviving copy and re-promotes
//!   (or recalls) instead of repaying the prefill. Entries carry an
//!   install-epoch identity, so a stream
//!   that held a pin on a quarantined entry can never unpin the fresh
//!   re-install another stream paid for: its pin is orphaned and its
//!   eventual unpin is a no-op. Re-installs after a quarantine go through
//!   the normal single-flight reservation, so N streams recovering the
//!   same representative still pay exactly one repaid prefill (or one
//!   re-promotion).
//! * **Handle conservation.** Every handle passed to [`install`] or
//!   [`admit_host`] leaves the pool exactly once — through a release
//!   vector, a [`Demotion`] work item, an [`Archival`] work item, a
//!   promotion checkout, a deferred graveyard drain, a quarantine sweep, a
//!   host-tier death, or the end-of-run [`SharedKvCache::drain_all`] — and
//!   is never returned while any stream pins it. `CacheStats::released`
//!   counts exactly the handles handed back **for disposal**, once each,
//!   at the call that returns them; handles leaving for *use* (demotions,
//!   archivals, promotion checkouts) are not counted until they come back
//!   for disposal through a later call. The property tests here and the
//!   concurrent suite in `rust/tests/shared_cache.rs` pin this down.
//!
//! Generic over the handle type so the policy is testable without a PJRT
//! engine; the real handle is [`crate::runtime::KvHandle`]. The pool never
//! talks to a backend itself — tier copies are **caller-mediated** work
//! items, which keeps the pool pure bookkeeping.
//!
//! [`bind`]: KvCacheManager::bind
//! [`lookup`]: KvCacheManager::lookup
//! [`install`]: KvCacheManager::install
//! [`abort_install`]: KvCacheManager::abort_install
//! [`release`]: KvCacheManager::release
//! [`expire`]: KvCacheManager::expire
//! [`quarantine_stale`]: KvCacheManager::quarantine_stale
//! [`admit_host`]: KvCacheManager::admit_host
//! [`budget_ok`]: SharedKvCache::budget_ok
//! [`consistent`]: SharedKvCache::consistent

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// Default shard count for new pools (a modest power of two: enough to
/// spread a few dozen streams, small enough that all-shard passes stay
/// cheap).
pub const DEFAULT_SHARDS: usize = 8;

/// Admission/eviction budget for the multi-resident, tiered cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CachePolicy {
    /// Total bytes of device-resident KV caches (k + v) the pool may hold.
    pub max_bytes: usize,
    /// Maximum number of concurrently device-resident representative caches.
    pub max_entries: usize,
    /// Byte budget of the host tier. `0` disables demotion entirely:
    /// eviction destroys the KV exactly as it did before the tier existed.
    pub host_bytes: usize,
    /// Byte budget of the disk tier (logical KV bytes of live archived
    /// records, mirroring the other two tiers). `0` disables archiving:
    /// host-budget deaths destroy the copy exactly as PR 7 did. Only
    /// meaningful with `host_bytes > 0` — the disk tier is fed by
    /// host-tier spills.
    pub disk_bytes: usize,
    /// Number of index shards (clamped to at least 1 at pool construction).
    pub shards: usize,
}

impl Default for CachePolicy {
    /// Multi-resident by default: up to 4 warm representatives, no byte cap
    /// (the simulated backbones are small; real deployments set `max_bytes`),
    /// host tier off, [`DEFAULT_SHARDS`] index shards.
    fn default() -> Self {
        CachePolicy {
            max_bytes: usize::MAX,
            max_entries: 4,
            host_bytes: 0,
            disk_bytes: 0,
            shards: DEFAULT_SHARDS,
        }
    }
}

impl CachePolicy {
    pub fn new(max_bytes: usize, max_entries: usize) -> Self {
        CachePolicy { max_bytes, max_entries, ..Self::default() }
    }

    /// No budget at all — every representative stays warm on the device.
    pub fn unbounded() -> Self {
        CachePolicy { max_bytes: usize::MAX, max_entries: usize::MAX, ..Self::default() }
    }

    /// The seed's behaviour: at most one resident representative.
    pub fn single_resident() -> Self {
        CachePolicy { max_entries: 1, ..Self::unbounded() }
    }

    /// Enable the host tier with the given byte budget (0 disables it).
    pub fn with_host_bytes(self, host_bytes: usize) -> Self {
        CachePolicy { host_bytes, ..self }
    }

    /// Enable the disk tier with the given byte budget (0 disables it).
    /// Host-budget LRU deaths then spill to the pool's archive file as
    /// [`Archival`] work items instead of dying.
    pub fn with_disk_bytes(self, disk_bytes: usize) -> Self {
        CachePolicy { disk_bytes, ..self }
    }

    /// Override the index shard count (clamped to ≥ 1 at construction).
    pub fn with_shards(self, shards: usize) -> Self {
        CachePolicy { shards, ..self }
    }
}

/// Accounting snapshot (reported in EXPERIMENTS.md and the table harnesses).
///
/// Returned both per stream ([`KvCacheManager::stats`] — the view's own
/// lookups/installs, with pool-level residency) and for the whole pool
/// ([`SharedKvCache::stats`]). Per-view `prefills`/`hits`/`misses`/
/// `evictions`/`released` and the tier counters (`demotions`/`promotions`/
/// `host_hits`/`archived`/`recalls`/`disk_hits`) sum to the pool's across
/// all views.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Installs = representative prefills actually paid (a promotion is
    /// counted in `promotions` instead — it repays a copy, not a prefill).
    pub prefills: u64,
    /// Lookups that found a warm device-resident cache (including lookups
    /// that waited out another stream's in-flight install of the same key).
    pub hits: u64,
    /// Lookups that found no device entry (new cluster, evicted, or
    /// host-resident-only — see `host_hits`).
    pub misses: u64,
    /// Entries removed from the device tier by the budget policy, whether
    /// they died (counted in `released` too) or left as [`Demotion`] work
    /// items (not released — the handle leaves for use, not disposal).
    pub evictions: u64,
    /// Handles handed back to a caller for **disposal**, each counted
    /// exactly once at the call that returns it: budget eviction deaths,
    /// same-key replacements, rejected installs, superseded host copies,
    /// host-tier deaths, explicit releases, quarantine sweeps, and
    /// graveyard drains. Handles parked in the graveyard count when a
    /// drain *returns* them, not when they enter. Handles handed back for
    /// **use** are never counted here: a device handle leaving inside a
    /// [`Demotion`] (consumed by `Backend::demote_kv`), a host handle
    /// leaving inside an [`Archival`] (consumed by `Backend::archive_kv`),
    /// and a promotion checkout (consumed by the copy-up) all count only
    /// if and when they come back for disposal through a later call.
    pub released: u64,
    /// KV bytes of prefill work avoided: sum of entry bytes over hits.
    pub bytes_saved: u64,
    /// Hits on an entry some *other* stream installed — the cross-stream
    /// deduplication the shared pool exists for (subset of `hits`).
    pub shared_hits: u64,
    /// KV bytes of prefill work another stream paid for us: sum of entry
    /// bytes over `shared_hits` (subset of `bytes_saved`).
    pub dedup_bytes_saved: u64,
    /// Releases deferred past a foreign pin (entry doomed, handle returned
    /// later through a graveyard drain).
    pub deferred_releases: u64,
    /// Entries invalidated by [`KvCacheManager::quarantine_stale`] because
    /// their device handles belonged to a dead lane incarnation (subset of
    /// `released`). Host-tier copies are never quarantined.
    pub quarantined: u64,
    /// Evicted device entries actually admitted to the host tier
    /// (counted at [`KvCacheManager::admit_host`]; redundant copies —
    /// the key re-resident by admission time — are released instead).
    pub demotions: u64,
    /// Host-tier copies re-installed on the device via
    /// [`KvCacheManager::install_promoted`] (counted instead of
    /// `prefills`).
    pub promotions: u64,
    /// Lookups that found a host-tier copy (subset of `misses`: the caller
    /// still pays the promotion copy, just not the full prefill).
    pub host_hits: u64,
    /// Host-tier spills actually written to the disk archive (counted at
    /// [`KvCacheManager::admit_disk`]; redundant or unwritable payloads
    /// are dropped instead).
    pub archived: u64,
    /// Archived records walked disk → host → device via
    /// [`KvCacheManager::install_recalled`] (counted instead of
    /// `prefills`, like `promotions`).
    pub recalls: u64,
    /// Lookups that found (and checked out) an archived disk record
    /// (subset of `misses`: the caller still pays the recall walk, just
    /// not the full prefill).
    pub disk_hits: u64,
    pub resident_bytes: usize,
    pub peak_bytes: usize,
    /// Bytes currently resident in the host tier (residency snapshot, like
    /// `resident_bytes`).
    pub host_bytes: usize,
    /// Logical KV bytes of live archived records (residency snapshot, like
    /// `host_bytes`).
    pub disk_bytes: usize,
}

impl CacheStats {
    /// Warm-hit rate over all lookups (0.0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 { 0.0 } else { self.hits as f64 / total as f64 }
    }
}

/// Per-shard lock contention counters. [`SharedKvCache::lock_stats`] sums
/// them across shards; [`SharedKvCache::shard_lock_stats`] exposes the
/// per-shard split (the signal that says whether the shard count is right).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LockStats {
    /// Lock acquisitions by any view/pool operation.
    pub acquisitions: u64,
    /// Acquisitions that found the lock held and had to block.
    pub contended: u64,
}

/// Content-hash identity of a representative: what makes two streams'
/// cluster representatives "the same" for KV-cache sharing. Build one with
/// [`RepKey::of_parts`] over everything that determines the prefilled
/// prefix (backbone name, graph name, representative node/edge ids) — the
/// verbalizer and tokenizer are deterministic, so equal parts imply a
/// bit-identical prefill.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RepKey(pub u64);

impl RepKey {
    /// FNV-1a over a byte stream assembled from string and integer parts.
    pub fn of_parts<'a, S, I>(strings: S, ids: I) -> RepKey
    where
        S: IntoIterator<Item = &'a str>,
        I: IntoIterator<Item = u64>,
    {
        let mut h = 0xcbf29ce484222325u64;
        let mut eat = |b: u64| {
            h = (h ^ b).wrapping_mul(0x100000001b3);
        };
        for s in strings {
            for &b in s.as_bytes() {
                eat(b as u64);
            }
            eat(0xFF); // separator so ("ab","c") != ("a","bc")
        }
        for id in ids {
            eat(id);
            eat(0xFE);
        }
        RepKey(h)
    }
}

/// Outcome of a [`KvCacheManager::lookup`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[must_use = "a MustInstall/MustPromote/MustRecall outcome carries a \
              reservation that must be installed, promoted/recalled, or \
              aborted"]
pub enum Lookup {
    /// Warm device entry found (possibly after waiting out another stream's
    /// in-flight install). The caller now holds one pin.
    Hit,
    /// Nothing resident in any tier. The caller holds the key's install
    /// reservation and must `install` or `abort_install` it (dropping the
    /// view also aborts).
    MustInstall,
    /// A host-tier copy was found and **checked out** (take it with
    /// [`KvCacheManager::take_promotion`]). The caller holds the key's
    /// reservation and must copy the KV back up and
    /// [`install_promoted`](KvCacheManager::install_promoted) it, or
    /// `abort_install` (which destroys the host copy). Callers that do not
    /// speak the tier protocol may treat this as a miss and `install` a
    /// fresh prefill — the abandoned checkout is buried and drained.
    MustPromote,
    /// An archived disk record was found, checksum-verified, and
    /// **checked out** (take the bytes with
    /// [`KvCacheManager::take_recall`]). The caller holds the key's
    /// reservation and must rebuild the KV (`Backend::recall_kv`, then the
    /// promote path) and [`install_recalled`](KvCacheManager::install_recalled)
    /// it, or `abort_install`. The record is already consumed — an
    /// abandoned recall loses only the disk copy (its bytes are not a
    /// backend handle, so there is nothing to bury). Callers that do not
    /// speak the tier protocol may treat this as a miss and `install` a
    /// fresh prefill.
    MustRecall,
}

impl Lookup {
    pub fn is_hit(self) -> bool {
        matches!(self, Lookup::Hit)
    }
}

// ---------------------------------------------------------------------------
// Tier work items
// ---------------------------------------------------------------------------

/// Identity + size of a demoted entry, minted by the pool at eviction and
/// handed back with the host handle at [`KvCacheManager::admit_host`].
/// Fields are pool-private so a slot can only come from a real demotion.
#[derive(Debug, Clone, Copy)]
pub struct HostSlot {
    key: u64,
    bytes: usize,
}

impl HostSlot {
    /// KV bytes of the demoted entry (what the host copy will occupy).
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// A demotion work item: budget eviction under an enabled host tier hands
/// the caller the device `handle` plus the `slot` identifying it. The
/// caller copies the KV off-device (`Backend::demote_kv` consumes the
/// device handle) and completes with
/// [`KvCacheManager::admit_host`]`(slot, host_handle)`; if the copy fails,
/// simply dropping the item loses only the host-tier opportunity.
#[must_use = "carry out the demotion (backend.demote_kv + admit_host) or \
              release the device handle"]
#[derive(Debug)]
pub struct Demotion<H> {
    pub handle: H,
    pub slot: HostSlot,
}

/// Result of a tier-aware install: handles to release on the backend now,
/// plus demotion work items to carry out (empty when the host tier is
/// disabled).
#[must_use = "release the handles and carry out the demotions"]
#[derive(Debug)]
pub struct TieredOut<H> {
    pub release: Vec<H>,
    pub demote: Vec<Demotion<H>>,
}

impl<H> TieredOut<H> {
    /// Flatten into plain release handles, dropping the host-tier
    /// opportunity (the compat path for callers that predate the tiers).
    pub fn into_release_all(self) -> Vec<H> {
        let mut out = self.release;
        out.extend(self.demote.into_iter().map(|d| d.handle));
        out
    }
}

/// Identity + size of a host copy spilling to the disk tier, minted by the
/// pool at a host-budget death and handed back with the serialized payload
/// at [`KvCacheManager::admit_disk`]. Fields are pool-private so a slot can
/// only come from a real spill.
#[derive(Debug, Clone, Copy)]
pub struct DiskSlot {
    key: u64,
    bytes: usize,
}

impl DiskSlot {
    /// Logical KV bytes of the spilled entry (what the disk budget counts).
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// An archival work item: a host-budget LRU death under an enabled disk
/// tier hands the caller the host `handle` plus the `slot` identifying it.
/// The caller serializes the KV (`Backend::archive_kv` consumes the host
/// handle either way) and completes with
/// [`KvCacheManager::admit_disk`]`(slot, payload)`; if serialization
/// fails, simply dropping the item loses only the disk-tier opportunity.
#[must_use = "carry out the archival (backend.archive_kv + admit_disk) or \
              release the host handle"]
#[derive(Debug)]
pub struct Archival<H> {
    pub handle: H,
    pub slot: DiskSlot,
}

/// Result of a host-tier admission: handles to release on the backend now
/// (LRU host deaths with the disk tier off, or a redundant copy), plus
/// archival work items to carry out (disk tier on; empty otherwise).
#[must_use = "release the handles and carry out the archivals"]
#[derive(Debug)]
pub struct HostAdmit<H> {
    pub release: Vec<H>,
    pub archive: Vec<Archival<H>>,
}

impl<H> HostAdmit<H> {
    /// Flatten into plain release handles, dropping the disk-tier
    /// opportunity (the compat path for callers that predate the archive).
    pub fn into_release_all(self) -> Vec<H> {
        let mut out = self.release;
        out.extend(self.archive.into_iter().map(|a| a.handle));
        out
    }
}

// ---------------------------------------------------------------------------
// Disk-tier archive
// ---------------------------------------------------------------------------

/// Bytes of one archive record's frame header:
/// `[key u64][kv_bytes u64][len u32][checksum u64]`, all little-endian,
/// followed by `len` payload bytes.
const FRAME_HEADER: u64 = 8 + 8 + 4 + 8;

/// FNV-1a over a payload — the frame checksum. Cheap, std-only, and enough
/// to catch a torn tail or a flipped bit: this is corruption *detection*
/// for crash-partial records, not authentication.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// In-memory index entry over one live archive record.
struct DiskRecord {
    /// File offset of the record's frame header.
    offset: u64,
    /// Payload length in bytes (the serialized form).
    len: u32,
    /// FNV-1a of the payload as written; a checkout re-verifies the
    /// on-disk copy against it.
    checksum: u64,
    /// Logical KV bytes of the entry (what the device copy occupied). The
    /// disk budget and the `disk_bytes` gauge count these, mirroring the
    /// other two tiers.
    kv_bytes: usize,
    last_used: u64,
}

/// Monotonic suffix so two pools in one process never share an archive
/// file.
static ARCHIVE_SEQ: AtomicU64 = AtomicU64::new(0);

/// The pool's append-only disk archive: a lazily created temp file of
/// framed records plus the in-memory index over the live ones. Locked
/// *after* any shard locks (shards → graveyard → archive, never the
/// reverse). See the module docs for the framing and compaction contract.
struct ArchiveInner {
    /// Created on the first appended record, deleted on drop.
    file: Option<std::fs::File>,
    path: std::path::PathBuf,
    /// Append offset (the file is never read past this).
    file_len: u64,
    /// key → live record.
    index: HashMap<u64, DiskRecord>,
    /// Logical KV bytes of live records (the budget gauge).
    live: usize,
    /// File bytes (frame + payload) of live / dead records. Dead bytes
    /// only shrink when compaction rewrites the file without them.
    live_file: u64,
    dead_file: u64,
    /// Records ever appended (the pool-level `archived` counter).
    archived: u64,
    compactions: u64,
}

impl ArchiveInner {
    fn new() -> Self {
        let path = std::env::temp_dir().join(format!(
            "subgcache-kvarc-{}-{}.dat",
            std::process::id(),
            ARCHIVE_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        ArchiveInner {
            file: None,
            path,
            file_len: 0,
            index: HashMap::new(),
            live: 0,
            live_file: 0,
            dead_file: 0,
            archived: 0,
            compactions: 0,
        }
    }

    fn open(&mut self) -> std::io::Result<&std::fs::File> {
        if self.file.is_none() {
            self.file = Some(
                std::fs::OpenOptions::new()
                    .read(true)
                    .write(true)
                    .create(true)
                    .truncate(true)
                    .open(&self.path)?,
            );
        }
        Ok(self.file.as_ref().expect("just opened"))
    }

    /// Append one framed record and index it. On any I/O error the record
    /// is not indexed — the spill opportunity is lost, nothing corrupts.
    fn append(&mut self, key: u64, kv_bytes: usize, last_used: u64, payload: &[u8])
              -> std::io::Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        let checksum = fnv1a(payload);
        let len = payload.len() as u32;
        let offset = self.file_len;
        let mut frame = Vec::with_capacity(FRAME_HEADER as usize + payload.len());
        frame.extend_from_slice(&key.to_le_bytes());
        frame.extend_from_slice(&(kv_bytes as u64).to_le_bytes());
        frame.extend_from_slice(&len.to_le_bytes());
        frame.extend_from_slice(&checksum.to_le_bytes());
        frame.extend_from_slice(payload);
        let mut file = self.open()?;
        file.seek(SeekFrom::Start(offset))?;
        file.write_all(&frame)?;
        self.file_len = offset + frame.len() as u64;
        self.live_file += frame.len() as u64;
        self.live += kv_bytes;
        self.archived += 1;
        self.index.insert(
            key,
            DiskRecord { offset, len, checksum, kv_bytes, last_used },
        );
        Ok(())
    }

    /// Drop `key`'s record from the index (superseded, released, or
    /// budget-killed), leaving its file bytes dead until compaction.
    /// Returns whether a live record existed.
    fn kill(&mut self, key: u64) -> bool {
        match self.index.remove(&key) {
            Some(rec) => {
                self.live -= rec.kv_bytes;
                let file_bytes = FRAME_HEADER + rec.len as u64;
                self.live_file -= file_bytes;
                self.dead_file += file_bytes;
                true
            }
            None => false,
        }
    }

    /// Check `key`'s record out: read its payload back, verify length and
    /// checksum, and consume the record either way. `Some((payload,
    /// kv_bytes))` on a clean read; `None` when no record exists or the
    /// on-disk bytes are torn (crash-partial write) — the torn record is
    /// dropped and the caller treats the lookup as a plain miss.
    fn checkout(&mut self, key: u64) -> Option<(Vec<u8>, usize)> {
        use std::io::{Read, Seek, SeekFrom};
        if !self.index.contains_key(&key) {
            return None;
        }
        let (offset, len, checksum, kv_bytes) = {
            let rec = &self.index[&key];
            (rec.offset, rec.len, rec.checksum, rec.kv_bytes)
        };
        // consumed either way: a clean checkout hands the bytes out, a
        // torn record must not be offered again.
        self.kill(key);
        let mut payload = vec![0u8; len as usize];
        let file = self.file.as_ref()?;
        let read = (|| -> std::io::Result<()> {
            let mut f = file;
            // verify the frame header too: a record whose header bytes
            // never hit the disk is as torn as a short payload.
            let mut header = [0u8; FRAME_HEADER as usize];
            f.seek(SeekFrom::Start(offset))?;
            f.read_exact(&mut header)?;
            let hkey = u64::from_le_bytes(header[0..8].try_into().unwrap());
            let hlen = u32::from_le_bytes(header[16..20].try_into().unwrap());
            if hkey != key || hlen != len {
                return Err(std::io::ErrorKind::InvalidData.into());
            }
            f.read_exact(&mut payload)?;
            Ok(())
        })();
        if read.is_err() || fnv1a(&payload) != checksum {
            return None;
        }
        Some((payload, kv_bytes))
    }

    /// Rewrite the file with only the live records once dead bytes exceed
    /// live bytes (the compaction watermark). Records whose bytes fail to
    /// read back cleanly are dropped — compaction never propagates a torn
    /// record. On an unwritable temp file the archive is left as-is (the
    /// dead bytes cost disk space, not correctness).
    fn maybe_compact(&mut self) {
        use std::io::{Read, Seek, SeekFrom, Write};
        if self.dead_file <= self.live_file || self.dead_file == 0 {
            return;
        }
        let Some(file) = self.file.as_ref() else { return };
        // read every live payload up front (verified), then rewrite.
        let mut survivors: Vec<(u64, DiskRecord, Vec<u8>)> = Vec::new();
        for (&key, rec) in self.index.iter() {
            let mut payload = vec![0u8; rec.len as usize];
            let ok = {
                let mut f = file;
                f.seek(SeekFrom::Start(rec.offset + FRAME_HEADER)).is_ok()
                    && f.read_exact(&mut payload).is_ok()
                    && fnv1a(&payload) == rec.checksum
            };
            if ok {
                survivors.push((
                    key,
                    DiskRecord { offset: 0, ..*rec },
                    payload,
                ));
            }
        }
        let tmp = self.path.with_extension("tmp");
        let rewrite = (|| -> std::io::Result<(std::fs::File, u64)> {
            let mut f = std::fs::OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(&tmp)?;
            let mut off = 0u64;
            for (key, rec, payload) in survivors.iter_mut() {
                rec.offset = off;
                f.write_all(&key.to_le_bytes())?;
                f.write_all(&(rec.kv_bytes as u64).to_le_bytes())?;
                f.write_all(&rec.len.to_le_bytes())?;
                f.write_all(&rec.checksum.to_le_bytes())?;
                f.write_all(payload)?;
                off += FRAME_HEADER + rec.len as u64;
            }
            f.flush()?;
            std::fs::rename(&tmp, &self.path)?;
            Ok((f, off))
        })();
        let Ok((f, off)) = rewrite else {
            let _ = std::fs::remove_file(&tmp);
            return;
        };
        self.file = Some(f);
        self.file_len = off;
        self.dead_file = 0;
        self.live_file = off;
        self.live = survivors.iter().map(|(_, r, _)| r.kv_bytes).sum();
        self.index = survivors
            .into_iter()
            .map(|(key, rec, _)| (key, rec))
            .collect();
        self.compactions += 1;
    }

    /// End-of-run reset: drop every record and truncate the file.
    fn clear(&mut self) {
        self.index.clear();
        self.live = 0;
        self.live_file = 0;
        self.dead_file = 0;
        self.file_len = 0;
        if let Some(f) = self.file.as_ref() {
            let _ = f.set_len(0);
        }
    }
}

impl Drop for ArchiveInner {
    fn drop(&mut self) {
        if self.file.take().is_some() {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

// ---------------------------------------------------------------------------
// Shared pool
// ---------------------------------------------------------------------------

/// One device-resident representative cache.
struct Entry<H> {
    key: u64,
    handle: H,
    bytes: usize,
    /// total pins across ALL streams.
    pins: u32,
    last_used: u64,
    /// stream id of the view whose install paid the prefill.
    installer: u64,
    /// release was requested while pinned: the handle moves to the
    /// graveyard when the last pin drops (unless a hit resurrects it).
    doomed: bool,
    /// Install-epoch identity (a pool-global tick at admission, unique per
    /// install). Distinguishes this entry from a later re-install under the
    /// same key, so a pin orphaned by a quarantine can never unpin the
    /// fresh entry that replaced its target.
    epoch: u64,
}

/// One host-tier copy. Host entries are never pinned and never doomed:
/// their whole lifecycle is admit → (checkout-for-promotion | LRU death |
/// superseded-by-install).
struct HostEntry<H> {
    key: u64,
    handle: H,
    bytes: usize,
    last_used: u64,
}

/// One shard of the index: its own mutex + condvar + contention counters.
/// A key's device entry, host copy, and pending reservation all live in
/// the same shard (`key % shards`).
struct Shard<H> {
    inner: Mutex<Inner<H>>,
    /// Wakes lookups blocked on a pending install in THIS shard.
    cv: Condvar,
    acquisitions: AtomicU64,
    contended: AtomicU64,
}

struct Inner<H> {
    entries: Vec<Entry<H>>,
    /// host-tier copies of keys owned by this shard.
    host: Vec<HostEntry<H>>,
    /// key → reserving stream id: a miss whose install is in flight.
    pending: HashMap<u64, u64>,
    /// this shard's share of the pool counters (residency fields unused —
    /// residency lives in the pool atomics; `SharedKvCache::stats` sums
    /// the shards and fills residency in).
    stats: CacheStats,
}

/// How an install is accounted: a paid prefill, a repaid host copy, or a
/// recalled disk record.
#[derive(Clone, Copy)]
enum Admit {
    Prefill,
    Promote,
    Recall,
}

/// What a lookup found, pool-side.
enum Found<H> {
    Hit { bytes: usize, shared: bool, epoch: u64 },
    /// Host copy checked out; the key is now reserved by the caller.
    Promote { handle: H, bytes: usize },
    /// Archived disk record checked out (read, verified, and consumed);
    /// the key is now reserved by the caller.
    Recall { payload: Vec<u8>, bytes: usize },
    /// Nothing in any tier; the key is now reserved by the caller.
    Reserved,
}

/// Outcome details handed back to the view so per-stream stats stay exact.
struct InstallOutcome<H> {
    /// Handles safe to hand to the backend (evictions under a disabled
    /// host tier, replacements, rejected duplicates, superseded host
    /// copies, drained graveyard).
    out: Vec<H>,
    /// Demotion work items (host tier enabled; empty otherwise).
    demote: Vec<Demotion<H>>,
    /// How many device entries the budget pass evicted (died or demoted).
    evictions: u64,
    /// Install-epoch of the entry the caller now holds a pin on (the fresh
    /// entry, or the pinned resident that rejected the install).
    epoch: u64,
}

/// The process-wide, thread-safe, byte-budgeted, two-tier KV cache pool.
/// `H` is an opaque device-cache handle; see the module docs for the full
/// contract. All mutation goes through [`KvCacheManager`] views; the pool
/// itself exposes only observation ([`stats`], [`lock_stats`],
/// [`resident_bytes`], [`host_resident_bytes`]) and end-of-run draining
/// ([`drain_all`], [`collect_deferred`]).
///
/// [`stats`]: SharedKvCache::stats
/// [`lock_stats`]: SharedKvCache::lock_stats
/// [`resident_bytes`]: SharedKvCache::resident_bytes
/// [`host_resident_bytes`]: SharedKvCache::host_resident_bytes
/// [`drain_all`]: SharedKvCache::drain_all
/// [`collect_deferred`]: SharedKvCache::collect_deferred
pub struct SharedKvCache<H> {
    policy: CachePolicy,
    shards: Box<[Shard<H>]>,
    /// Deferred-release handles (doomed entries whose last pin dropped,
    /// abandoned promotion checkouts). Pool-level because every
    /// handle-returning call on ANY key drains the full backlog. Lock
    /// order: shards → graveyard, never the reverse.
    graveyard: Mutex<Vec<H>>,
    /// Pool-global LRU / epoch clock (mutated with a bare `fetch_add`, so
    /// epochs stay unique across shards).
    tick: AtomicU64,
    /// Device-tier residency. Mutated only under the owning shard's lock;
    /// an all-shards holder therefore reads a stable snapshot.
    resident: AtomicUsize,
    peak: AtomicUsize,
    /// Host-tier residency (same locking discipline as `resident`).
    host_resident: AtomicUsize,
    /// Device-tier entry count across shards.
    entry_count: AtomicUsize,
    next_stream: AtomicU64,
    /// Disk-tier archive (`None` when `CachePolicy::disk_bytes == 0`).
    /// Lock order: any shard locks → graveyard → archive, never the
    /// reverse.
    disk: Option<Mutex<ArchiveInner>>,
}

impl<H> SharedKvCache<H> {
    pub fn new(policy: CachePolicy) -> Self {
        assert!(policy.max_entries >= 1, "policy must admit at least one entry");
        let nshards = policy.shards.max(1);
        let shards = (0..nshards)
            .map(|_| Shard {
                inner: Mutex::new(Inner {
                    entries: Vec::new(),
                    host: Vec::new(),
                    pending: HashMap::new(),
                    stats: CacheStats::default(),
                }),
                cv: Condvar::new(),
                acquisitions: AtomicU64::new(0),
                contended: AtomicU64::new(0),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        SharedKvCache {
            policy,
            shards,
            graveyard: Mutex::new(Vec::new()),
            tick: AtomicU64::new(0),
            resident: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            host_resident: AtomicUsize::new(0),
            entry_count: AtomicUsize::new(0),
            next_stream: AtomicU64::new(1),
            disk: (policy.disk_bytes > 0).then(|| Mutex::new(ArchiveInner::new())),
        }
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    fn shard(&self, key: u64) -> &Shard<H> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    /// Lock one shard, counting contention. Mutex poisoning is recovered:
    /// every critical section below restores invariants before returning,
    /// so a panicking test thread must not cascade into every other stream.
    fn lock_shard<'a>(&'a self, sh: &'a Shard<H>) -> MutexGuard<'a, Inner<H>> {
        sh.acquisitions.fetch_add(1, Ordering::Relaxed);
        match sh.inner.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::WouldBlock) => {
                sh.contended.fetch_add(1, Ordering::Relaxed);
                sh.inner.lock().unwrap_or_else(PoisonError::into_inner)
            }
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
        }
    }

    /// Lock every shard in ascending index order (the cross-shard passes'
    /// deadlock-freedom rule).
    fn lock_all(&self) -> Vec<MutexGuard<'_, Inner<H>>> {
        self.shards.iter().map(|sh| self.lock_shard(sh)).collect()
    }

    fn lock_graveyard(&self) -> MutexGuard<'_, Vec<H>> {
        self.graveyard.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Lock the disk archive (`None` when the disk tier is disabled).
    /// Always acquired after any shard/graveyard locks held.
    fn lock_disk(&self) -> Option<MutexGuard<'_, ArchiveInner>> {
        self.disk
            .as_ref()
            .map(|m| m.lock().unwrap_or_else(PoisonError::into_inner))
    }

    /// Drain the deferred-release backlog into `out`, counting each drained
    /// handle as released at THIS call (the call that returns it).
    fn drain_graveyard_into(&self, out: &mut Vec<H>, stats: &mut CacheStats) {
        let mut g = self.lock_graveyard();
        stats.released += g.len() as u64;
        out.append(&mut g);
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn register_stream(&self) -> u64 {
        self.next_stream.fetch_add(1, Ordering::Relaxed)
    }

    /// Pool-wide contention counters, summed over shards (when `contended`
    /// grows a meaningful fraction of `acquisitions`, raise
    /// `CachePolicy::shards`).
    pub fn lock_stats(&self) -> LockStats {
        let mut total = LockStats::default();
        for sh in self.shards.iter() {
            total.acquisitions += sh.acquisitions.load(Ordering::Relaxed);
            total.contended += sh.contended.load(Ordering::Relaxed);
        }
        total
    }

    /// Per-shard contention split (diagnostics: a single hot shard means a
    /// skewed key population, not an undersized shard count).
    pub fn shard_lock_stats(&self) -> Vec<LockStats> {
        self.shards
            .iter()
            .map(|sh| LockStats {
                acquisitions: sh.acquisitions.load(Ordering::Relaxed),
                contended: sh.contended.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Pool-level accounting: totals across every view, shard by shard,
    /// with residency snapshotted from the pool atomics.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for sh in self.shards.iter() {
            let inner = self.lock_shard(sh);
            let s = inner.stats;
            total.prefills += s.prefills;
            total.hits += s.hits;
            total.misses += s.misses;
            total.evictions += s.evictions;
            total.released += s.released;
            total.bytes_saved += s.bytes_saved;
            total.shared_hits += s.shared_hits;
            total.dedup_bytes_saved += s.dedup_bytes_saved;
            total.deferred_releases += s.deferred_releases;
            total.quarantined += s.quarantined;
            total.demotions += s.demotions;
            total.promotions += s.promotions;
            total.host_hits += s.host_hits;
            total.recalls += s.recalls;
            total.disk_hits += s.disk_hits;
        }
        total.resident_bytes = self.resident.load(Ordering::Relaxed);
        total.peak_bytes = self.peak.load(Ordering::Relaxed);
        total.host_bytes = self.host_resident.load(Ordering::Relaxed);
        if let Some(arc) = self.lock_disk() {
            total.archived = arc.archived;
            total.disk_bytes = arc.live;
        }
        total
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident.load(Ordering::Relaxed)
    }

    /// Bytes currently parked in the host tier.
    pub fn host_resident_bytes(&self) -> usize {
        self.host_resident.load(Ordering::Relaxed)
    }

    /// Device-resident entries across all shards.
    pub fn len(&self) -> usize {
        self.entry_count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host-tier entries across all shards.
    pub fn host_len(&self) -> usize {
        self.shards.iter().map(|sh| self.lock_shard(sh).host.len()).sum()
    }

    /// Logical KV bytes of live archived records (0 with the disk tier
    /// off).
    pub fn disk_resident_bytes(&self) -> usize {
        self.lock_disk().map_or(0, |arc| arc.live)
    }

    /// Live archived records in the disk tier.
    pub fn disk_len(&self) -> usize {
        self.lock_disk().map_or(0, |arc| arc.index.len())
    }

    /// Archive-file compaction passes run so far (diagnostics; the tests
    /// use it to pin the dead-bytes watermark).
    pub fn disk_compactions(&self) -> u64 {
        self.lock_disk().map_or(0, |arc| arc.compactions)
    }

    /// Path of the archive file (diagnostics and fault-injection tests;
    /// `None` with the disk tier off). The file exists only once a record
    /// has been archived, and is deleted with the pool.
    pub fn disk_archive_path(&self) -> Option<std::path::PathBuf> {
        self.lock_disk().map(|arc| arc.path.clone())
    }

    /// True while the pool satisfies its device budget — or cannot (every
    /// resident entry pinned), in which case running over budget is the
    /// contract. This is the **install-point** invariant: eviction only
    /// runs at install, so between a pinned overrun's unpin and the next
    /// install the pool may legitimately sit over budget with evictable
    /// entries (the same window the single-stream property tests have
    /// always allowed). `install` re-asserts it under all shard locks on
    /// every call; use [`consistent`](Self::consistent) for anytime polling
    /// instead.
    pub fn budget_ok(&self) -> bool {
        let guards = self.lock_all();
        self.budget_ok_locked(&guards)
    }

    fn budget_ok_locked(&self, guards: &[MutexGuard<'_, Inner<H>>]) -> bool {
        let within = self.resident.load(Ordering::Relaxed) <= self.policy.max_bytes
            && self.entry_count.load(Ordering::Relaxed) <= self.policy.max_entries;
        within || guards.iter().all(|g| g.entries.iter().all(|e| e.pins > 0))
    }

    /// Anytime internal-consistency check for the concurrent property
    /// tests: byte/count accounting matches the entries in every tier,
    /// peak is monotone, a doomed entry is always pinned (a doomed entry
    /// losing its last pin is removed under the same lock), no pending
    /// install reservation shadows a resident key, and the tiers never
    /// hold two live copies of one key.
    pub fn consistent(&self) -> bool {
        let guards = self.lock_all();
        let bytes: usize = guards.iter().flat_map(|g| g.entries.iter()).map(|e| e.bytes).sum();
        let host_bytes: usize = guards.iter().flat_map(|g| g.host.iter()).map(|e| e.bytes).sum();
        let count: usize = guards.iter().map(|g| g.entries.len()).sum();
        let nshards = self.shards.len() as u64;
        let disk_ok = self.lock_disk().is_none_or(|arc| {
            arc.live == arc.index.values().map(|r| r.kv_bytes).sum::<usize>()
                && arc.index.keys().all(|&k| {
                    // an archived key must not be live in a higher tier.
                    let g = &guards[(k % nshards) as usize];
                    g.entries.iter().all(|e| e.key != k)
                        && g.host.iter().all(|h| h.key != k)
                })
        });
        bytes == self.resident.load(Ordering::Relaxed)
            && host_bytes == self.host_resident.load(Ordering::Relaxed)
            && count == self.entry_count.load(Ordering::Relaxed)
            && self.peak.load(Ordering::Relaxed) >= self.resident.load(Ordering::Relaxed)
            && disk_ok
            && guards.iter().all(|g| {
                g.entries.iter().all(|e| !e.doomed || e.pins > 0)
                    && g.entries.iter().all(|e| !g.pending.contains_key(&e.key))
                    && g.host
                        .iter()
                        .all(|h| g.entries.iter().all(|e| e.key != h.key))
            })
    }

    /// Drain every resident entry in **both tiers** and the graveyard,
    /// pinned or not. Quiescent-only: call after every stream using the
    /// pool has finished (pins left by an unwound stream are abandoned
    /// bookkeeping by then). Every drained handle counts as released here —
    /// the call that returns it.
    pub fn drain_all(&self) -> Vec<H> {
        let mut guards = self.lock_all();
        let mut out = Vec::new();
        for g in guards.iter_mut() {
            let n = g.entries.len() + g.host.len();
            out.extend(g.entries.drain(..).map(|e| e.handle));
            out.extend(g.host.drain(..).map(|e| e.handle));
            g.stats.released += n as u64;
        }
        {
            let mut grave = self.lock_graveyard();
            guards[0].stats.released += grave.len() as u64;
            out.append(&mut grave);
        }
        // archived records hold no backend handles: clearing the disk tier
        // truncates the file and bumps nothing in `released`.
        if let Some(mut arc) = self.lock_disk() {
            arc.clear();
        }
        self.resident.store(0, Ordering::Relaxed);
        self.host_resident.store(0, Ordering::Relaxed);
        self.entry_count.store(0, Ordering::Relaxed);
        out
    }

    /// Drain only the graveyard (deferred releases whose last pin dropped,
    /// abandoned promotion checkouts). Drained handles count as released
    /// here — the call that returns them.
    pub fn collect_deferred(&self) -> Vec<H> {
        let sh = &self.shards[0];
        let mut inner = self.lock_shard(sh);
        let mut out = Vec::new();
        self.drain_graveyard_into(&mut out, &mut inner.stats);
        out
    }

    // -- internal ops (called by views) -------------------------------------

    fn idx(inner: &Inner<H>, key: u64) -> Option<usize> {
        inner.entries.iter().position(|e| e.key == key)
    }

    fn host_idx(inner: &Inner<H>, key: u64) -> Option<usize> {
        inner.host.iter().position(|e| e.key == key)
    }

    fn over_budget(&self) -> bool {
        self.resident.load(Ordering::Relaxed) > self.policy.max_bytes
            || self.entry_count.load(Ordering::Relaxed) > self.policy.max_entries
    }

    /// Global LRU over unpinned device entries, across all locked shards.
    fn global_lru_unpinned(guards: &[MutexGuard<'_, Inner<H>>]) -> Option<(usize, usize)> {
        let mut pick: Option<(usize, usize, u64)> = None;
        for (si, g) in guards.iter().enumerate() {
            for (ei, e) in g.entries.iter().enumerate() {
                let colder = match pick {
                    None => true,
                    Some((_, _, lu)) => e.last_used < lu,
                };
                if e.pins == 0 && colder {
                    pick = Some((si, ei, e.last_used));
                }
            }
        }
        pick.map(|(si, ei, _)| (si, ei))
    }

    /// Evict device entries (global LRU, zero-pin only) until the device
    /// budget holds or only pinned entries remain. With the host tier
    /// enabled, victims leave as [`Demotion`] work items; otherwise they
    /// die. Runs under ALL shard locks; see the module locking rules.
    fn enforce_device_budget(&self) -> (Vec<H>, Vec<Demotion<H>>, u64) {
        let mut out = Vec::new();
        let mut demote = Vec::new();
        let mut evictions = 0u64;
        if !self.over_budget() {
            return (out, demote, evictions);
        }
        let mut guards = self.lock_all();
        while self.over_budget() {
            let Some((si, ei)) = Self::global_lru_unpinned(&guards) else {
                break; // only pinned entries left: run over budget
            };
            let e = guards[si].entries.swap_remove(ei);
            self.resident.fetch_sub(e.bytes, Ordering::Relaxed);
            self.entry_count.fetch_sub(1, Ordering::Relaxed);
            let stats = &mut guards[si].stats;
            stats.evictions += 1;
            evictions += 1;
            if self.policy.host_bytes > 0 {
                // demotion victims leave "for use": `demote_kv` consumes the
                // device handle, nobody hands it back for disposal, so
                // `released` is NOT bumped here (the handle-conservation
                // contract in the module docs).
                demote.push(Demotion {
                    handle: e.handle,
                    slot: HostSlot { key: e.key, bytes: e.bytes },
                });
            } else {
                stats.released += 1;
                out.push(e.handle);
            }
        }
        // the budget contract, asserted where it is defined — at the end of
        // every install's eviction pass, under all locks. A concurrent
        // install's pass fixes this one's overrun too, so the assert holds
        // for every interleaving.
        debug_assert!(
            self.budget_ok_locked(&guards),
            "install left the pool over budget with evictable entries"
        );
        (out, demote, evictions)
    }

    /// LRU enforcement of the host byte budget: drop the coldest host
    /// copies until the tier fits. With the disk tier enabled, victims
    /// leave as [`Archival`] work items (the handle is consumed by
    /// `archive_kv`, so `released` is NOT bumped); otherwise they die and
    /// are counted released here, the returning call. Host entries are
    /// never pinned, so this always converges.
    ///
    /// One scan total: victims are collected coldest-first in a single
    /// pass over every shard and popped in order, instead of rescanning
    /// every host entry per victim under ALL shard locks.
    fn enforce_host_budget(&self) -> (Vec<H>, Vec<Archival<H>>) {
        let mut out = Vec::new();
        let mut archive = Vec::new();
        if self.host_resident.load(Ordering::Relaxed) <= self.policy.host_bytes {
            return (out, archive);
        }
        let mut guards = self.lock_all();
        // single scan: (last_used, shard, key) for every host copy, coldest
        // first. Keys (not indices) are recorded so the per-victim
        // swap_remove below cannot invalidate later picks.
        let mut order: Vec<(u64, usize, u64)> = guards
            .iter()
            .enumerate()
            .flat_map(|(si, g)| g.host.iter().map(move |e| (e.last_used, si, e.key)))
            .collect();
        order.sort_unstable();
        let mut next = order.into_iter();
        while self.host_resident.load(Ordering::Relaxed) > self.policy.host_bytes {
            let Some((_, si, key)) = next.next() else { break };
            let Some(ei) = guards[si].host.iter().position(|e| e.key == key) else {
                continue;
            };
            let e = guards[si].host.swap_remove(ei);
            self.host_resident.fetch_sub(e.bytes, Ordering::Relaxed);
            if self.policy.disk_bytes > 0 {
                archive.push(Archival {
                    handle: e.handle,
                    slot: DiskSlot { key: e.key, bytes: e.bytes },
                });
            } else {
                guards[si].stats.released += 1;
                out.push(e.handle);
            }
        }
        (out, archive)
    }

    /// Hit-or-reserve; blocks while another stream's install of `key` is
    /// pending. A host-tier copy is checked out (and the key reserved) for
    /// the caller to promote.
    fn lookup_or_reserve(&self, stream: u64, key: u64) -> Found<H> {
        let sh = self.shard(key);
        let mut inner = self.lock_shard(sh);
        loop {
            if let Some(i) = Self::idx(&inner, key) {
                let t = self.next_tick();
                let e = &mut inner.entries[i];
                // a hit on a doomed entry resurrects it: it is demonstrably
                // still hot, and tearing it down under a fresh pin would
                // force the next stream into a pointless re-prefill.
                e.doomed = false;
                e.last_used = t;
                e.pins += 1;
                let bytes = e.bytes;
                let shared = e.installer != stream;
                let epoch = e.epoch;
                inner.stats.hits += 1;
                inner.stats.bytes_saved += bytes as u64;
                if shared {
                    inner.stats.shared_hits += 1;
                    inner.stats.dedup_bytes_saved += bytes as u64;
                }
                return Found::Hit { bytes, shared, epoch };
            }
            // copy the owner out so the map borrow ends before the guard
            // is moved into the condvar wait (NLL cannot see through a
            // match arm here).
            let owner = inner.pending.get(&key).copied();
            match owner {
                Some(owner) => {
                    assert_ne!(
                        owner, stream,
                        "stream looked up a key it already holds a reservation \
                         for (install or abort_install it first)"
                    );
                    inner = sh
                        .cv
                        .wait(inner)
                        .unwrap_or_else(PoisonError::into_inner);
                }
                None => {
                    inner.stats.misses += 1;
                    inner.pending.insert(key, stream);
                    if let Some(hi) = Self::host_idx(&inner, key) {
                        // host hit: check the copy out for promotion. The
                        // reservation keeps it single-flight — racing
                        // streams block above and then hit the promoted
                        // entry, paying one copy, never N.
                        let he = inner.host.swap_remove(hi);
                        self.host_resident.fetch_sub(he.bytes, Ordering::Relaxed);
                        inner.stats.host_hits += 1;
                        return Found::Promote { handle: he.handle, bytes: he.bytes };
                    }
                    if let Some(mut arc) = self.lock_disk() {
                        // disk hit: check the archived record out (read,
                        // verified, consumed) for the caller to recall. A
                        // torn record reads back None and the miss stands.
                        if let Some((payload, bytes)) = arc.checkout(key) {
                            arc.maybe_compact();
                            inner.stats.disk_hits += 1;
                            return Found::Recall { payload, bytes };
                        }
                    }
                    return Found::Reserved;
                }
            }
        }
    }

    /// Install `handle` under `key`, fulfilling `stream`'s reservation if
    /// one exists. The entry is admitted pinned (one pin for the caller).
    /// Colder zero-pin entries may be evicted (demoted, with the host tier
    /// enabled) to make room; if only pinned entries remain the pool runs
    /// over budget instead. `admit` selects the accounting: a paid prefill
    /// or a repaid promotion copy.
    fn install(
        &self,
        stream: u64,
        key: u64,
        handle: H,
        bytes: usize,
        admit: Admit,
    ) -> InstallOutcome<H> {
        let sh = self.shard(key);
        let mut inner = self.lock_shard(sh);
        // any reservation of this key — ours or another stream's blind-
        // raced one — is resolved by this install: the key is about to be
        // resident, so waiters wake into a hit and a reserving stream's
        // own later install lands on the resident branch (replace/reject).
        // A pending entry must never shadow a resident key.
        inner.pending.remove(&key);
        // peak is taken up front: the incoming cache coexists on the device
        // with every current resident — including any entries about to be
        // evicted or replaced — until the caller releases the returned
        // handles, so this transient sum is the honest high-water mark.
        self.peak
            .fetch_max(self.resident.load(Ordering::Relaxed) + bytes, Ordering::Relaxed);
        let mut out = Vec::new();
        self.drain_graveyard_into(&mut out, &mut inner.stats);
        // a resident install supersedes any host copy of the same content:
        // the tiers never hold two live copies of one key.
        if let Some(hi) = Self::host_idx(&inner, key) {
            let he = inner.host.swap_remove(hi);
            self.host_resident.fetch_sub(he.bytes, Ordering::Relaxed);
            inner.stats.released += 1;
            out.push(he.handle);
        }
        // ... and any archived disk record of it (records hold no backend
        // handles, so nothing is released by this kill).
        if let Some(mut arc) = self.lock_disk() {
            arc.kill(key);
        }
        let count_admit = |stats: &mut CacheStats| match admit {
            Admit::Prefill => stats.prefills += 1,
            Admit::Promote => stats.promotions += 1,
            Admit::Recall => stats.recalls += 1,
        };
        if let Some(i) = Self::idx(&inner, key) {
            // the key is already resident (e.g. another stream installed it
            // between this stream's reservation-free admission attempts, or
            // a rebuild raced an eviction). A pinned resident wins: some
            // stream's in-flight extend may hold it, so the only safe
            // answer is to keep it and hand the NEW handle straight back —
            // with a pin taken for the caller so its later unpin balances.
            if inner.entries[i].pins > 0 {
                let t = self.next_tick();
                let e = &mut inner.entries[i];
                e.pins += 1;
                e.last_used = t;
                let epoch = e.epoch;
                // the caller just re-demanded this content: a doomed entry
                // is resurrected, exactly as a lookup hit would.
                e.doomed = false;
                // the rejected install still PAID its prefill (or its
                // promotion copy — the handle goes straight back for
                // release): count it, so per-view counters always sum to
                // the pool's.
                count_admit(&mut inner.stats);
                inner.stats.released += 1;
                out.push(handle);
                sh.cv.notify_all();
                return InstallOutcome { out, demote: Vec::new(), evictions: 0, epoch };
            }
            // replacement is not budget pressure: count the returned handle
            // in `released` only, never in `evictions`.
            let e = inner.entries.swap_remove(i);
            inner.stats.released += 1;
            self.resident.fetch_sub(e.bytes, Ordering::Relaxed);
            self.entry_count.fetch_sub(1, Ordering::Relaxed);
            out.push(e.handle);
        }
        let t = self.next_tick();
        count_admit(&mut inner.stats);
        let new_resident = self.resident.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(new_resident, Ordering::Relaxed);
        self.entry_count.fetch_add(1, Ordering::Relaxed);
        inner.entries.push(Entry {
            key,
            handle,
            bytes,
            pins: 1,
            last_used: t,
            installer: stream,
            doomed: false,
            // the admission tick is unique per install across shards, so
            // it doubles as the entry's identity across re-installs.
            epoch: t,
        });
        // waiters blocked on this key's reservation can now hit it.
        sh.cv.notify_all();
        // the eviction pass needs ALL shard locks (ascending), so this
        // shard's must drop first. The fresh entry is pinned — never a
        // victim — and a concurrent pass can only help.
        drop(inner);
        let (evicted, demote, evictions) = self.enforce_device_budget();
        out.extend(evicted);
        InstallOutcome { out, demote, evictions, epoch: t }
    }

    /// Complete a demotion: park `host` (the off-device copy of the entry
    /// `slot` identifies) in the host tier. Returns the tiered work the
    /// admission forced — handles to release (LRU host-tier deaths under a
    /// disabled disk tier, plus `host` itself if the copy became redundant:
    /// the key is resident or host-parked again by the time the copy
    /// finished) and [`Archival`] spills (disk tier enabled). The bool
    /// reports whether the copy was admitted (a counted demotion).
    fn admit_host(&self, slot: HostSlot, host: H) -> (HostAdmit<H>, bool) {
        let sh = self.shard(slot.key);
        let mut inner = self.lock_shard(sh);
        let redundant = self.policy.host_bytes == 0
            || Self::idx(&inner, slot.key).is_some()
            || Self::host_idx(&inner, slot.key).is_some();
        if redundant {
            inner.stats.released += 1;
            return (HostAdmit { release: vec![host], archive: Vec::new() }, false);
        }
        let t = self.next_tick();
        self.host_resident.fetch_add(slot.bytes, Ordering::Relaxed);
        inner.stats.demotions += 1;
        inner.host.push(HostEntry { key: slot.key, handle: host, bytes: slot.bytes, last_used: t });
        drop(inner);
        let (release, archive) = self.enforce_host_budget();
        (HostAdmit { release, archive }, true)
    }

    /// Complete an archival: append `payload` (the serialized KV the
    /// backend produced from an [`Archival`]'s host handle) to the disk
    /// archive under `slot`'s key. Returns whether the record was admitted
    /// (a counted archival) — it is dropped instead if the disk tier is
    /// off, the payload outgrows the whole disk budget, the key is live in
    /// a higher tier again, or the append I/O fails (the archive is an
    /// optimization; an I/O error degrades to "not cached", never a
    /// panic). Coldest records are killed to make room, bumping nothing in
    /// `released` — disk records hold no backend handles.
    fn admit_disk(&self, slot: DiskSlot, payload: &[u8]) -> bool {
        if self.policy.disk_bytes == 0 || slot.bytes > self.policy.disk_bytes {
            return false;
        }
        let sh = self.shard(slot.key);
        let inner = self.lock_shard(sh);
        if Self::idx(&inner, slot.key).is_some() || Self::host_idx(&inner, slot.key).is_some() {
            return false;
        }
        let Some(mut arc) = self.lock_disk() else { return false };
        if arc.index.contains_key(&slot.key) {
            return false;
        }
        // evict coldest archived records until the new one fits the byte
        // budget (logical KV bytes, mirroring the host tier's accounting).
        while arc.live + slot.bytes > self.policy.disk_bytes {
            let Some((&victim, _)) =
                arc.index.iter().min_by_key(|(_, r)| r.last_used)
            else {
                break;
            };
            arc.kill(victim);
        }
        let t = self.next_tick();
        let admitted = arc.append(slot.key, slot.bytes, t, payload).is_ok();
        arc.maybe_compact();
        admitted
    }

    /// Park an abandoned handle (e.g. a promotion checkout whose copy-up
    /// failed or was never attempted) in the graveyard; it surfaces — and
    /// counts as released — at the next drain.
    fn bury(&self, handle: H) {
        self.lock_graveyard().push(handle);
    }

    /// Cancel `stream`'s reservation of `key` (error path). Waiters wake
    /// and re-race: one becomes the new installer.
    fn abort_install(&self, stream: u64, key: u64) {
        let sh = self.shard(key);
        let mut inner = self.lock_shard(sh);
        if inner.pending.get(&key) == Some(&stream) {
            inner.pending.remove(&key);
            sh.cv.notify_all();
        }
    }

    /// Borrow the resident handle of `key` under its shard lock. The
    /// closure must be short and non-blocking (it runs inside the shard's
    /// critical section) — enqueueing a backend submit is fine, waiting a
    /// ticket is not.
    fn with_handle<R>(&self, key: u64, f: impl FnOnce(&H) -> R) -> Option<R> {
        let inner = self.lock_shard(self.shard(key));
        Self::idx(&inner, key).map(|i| f(&inner.entries[i].handle))
    }

    fn contains(&self, key: u64) -> bool {
        let inner = self.lock_shard(self.shard(key));
        Self::idx(&inner, key).is_some()
    }

    /// Whether `key` has a host-tier copy (not a hit; no LRU refresh).
    fn contains_host(&self, key: u64) -> bool {
        let inner = self.lock_shard(self.shard(key));
        Self::host_idx(&inner, key).is_some()
    }

    /// Add one pin (nesting) to a resident entry. Returns the entry's
    /// epoch, or `None` if absent.
    fn pin(&self, key: u64) -> Option<u64> {
        let mut inner = self.lock_shard(self.shard(key));
        match Self::idx(&inner, key) {
            Some(i) => {
                inner.entries[i].pins += 1;
                Some(inner.entries[i].epoch)
            }
            None => None,
        }
    }

    /// Drop one pin taken on the entry incarnation identified by `epoch`.
    /// If that was the last pin of a doomed entry, the entry dies and its
    /// handle moves to the graveyard. A pin orphaned by a quarantine —
    /// its entry is gone, or the key is now a different incarnation — is
    /// resolved as a no-op: decrementing the fresh entry here would let
    /// eviction reclaim KV another stream's in-flight ticket still reads.
    fn unpin(&self, key: u64, epoch: u64) -> bool {
        let mut inner = self.lock_shard(self.shard(key));
        match Self::idx(&inner, key) {
            Some(i) if inner.entries[i].epoch == epoch && inner.entries[i].pins > 0 => {
                inner.entries[i].pins -= 1;
                if inner.entries[i].pins == 0 && inner.entries[i].doomed {
                    let e = inner.entries.swap_remove(i);
                    self.resident.fetch_sub(e.bytes, Ordering::Relaxed);
                    self.entry_count.fetch_sub(1, Ordering::Relaxed);
                    // parked, not returned: counts as released at the
                    // drain that surfaces it (shards → graveyard order).
                    self.bury(e.handle);
                }
                true
            }
            // orphaned pin: the incarnation it protected was quarantined.
            _ => true,
        }
    }

    /// Remove every **device** entry whose handle the predicate marks
    /// stale (its device state died with a lane incarnation), pinned or
    /// not — pins protect live device reads, and a dead incarnation has
    /// none left to protect. Host-tier copies are never swept: they do not
    /// live on the lane, so they survive and re-promote instead of
    /// repaying the prefill. Pins other streams hold on a removed entry
    /// become orphans: their epoch no longer matches anything, so their
    /// eventual unpin is a no-op rather than a corruption of a fresh
    /// re-install. Returns the dead handles (for bookkeeping release to
    /// the backend) plus any graveyard backlog, and the count quarantined.
    pub fn quarantine_stale(&self, mut is_stale: impl FnMut(&H) -> bool) -> (Vec<H>, u64) {
        let mut out = Vec::new();
        let mut quarantined = 0u64;
        for sh in self.shards.iter() {
            let mut inner = self.lock_shard(sh);
            let mut i = 0;
            while i < inner.entries.len() {
                if is_stale(&inner.entries[i].handle) {
                    let e = inner.entries.swap_remove(i);
                    self.resident.fetch_sub(e.bytes, Ordering::Relaxed);
                    self.entry_count.fetch_sub(1, Ordering::Relaxed);
                    inner.stats.released += 1;
                    inner.stats.quarantined += 1;
                    quarantined += 1;
                    out.push(e.handle);
                } else {
                    i += 1;
                }
            }
        }
        {
            let sh = &self.shards[0];
            let mut inner = self.lock_shard(sh);
            self.drain_graveyard_into(&mut out, &mut inner.stats);
        }
        (out, quarantined)
    }

    fn pin_count(&self, key: u64) -> u32 {
        let inner = self.lock_shard(self.shard(key));
        Self::idx(&inner, key).map(|i| inner.entries[i].pins).unwrap_or(0)
    }

    /// Release `key`'s entry. Unpinned: removed now, handle returned (plus
    /// any graveyard backlog). Pinned by anyone: the entry is doomed and
    /// its handle deferred to the graveyard at last unpin. A host-tier
    /// copy of the key dies with it (release means "this content is
    /// cold"). Returns `(handles, deferred?)`.
    fn release(&self, key: u64) -> (Vec<H>, bool) {
        let sh = self.shard(key);
        let mut inner = self.lock_shard(sh);
        let mut out = Vec::new();
        self.drain_graveyard_into(&mut out, &mut inner.stats);
        let mut deferred = false;
        if let Some(i) = Self::idx(&inner, key) {
            if inner.entries[i].pins > 0 {
                inner.entries[i].doomed = true;
                inner.stats.deferred_releases += 1;
                deferred = true;
            } else {
                let e = inner.entries.swap_remove(i);
                inner.stats.released += 1;
                self.resident.fetch_sub(e.bytes, Ordering::Relaxed);
                self.entry_count.fetch_sub(1, Ordering::Relaxed);
                out.push(e.handle);
            }
        }
        if let Some(hi) = Self::host_idx(&inner, key) {
            let he = inner.host.swap_remove(hi);
            self.host_resident.fetch_sub(he.bytes, Ordering::Relaxed);
            inner.stats.released += 1;
            out.push(he.handle);
        }
        // an archived disk record of the key dies too (no backend handle,
        // so nothing joins `out` and `released` is untouched).
        if let Some(mut arc) = self.lock_disk() {
            arc.kill(key);
        }
        (out, deferred)
    }
}

// ---------------------------------------------------------------------------
// Per-stream view
// ---------------------------------------------------------------------------

/// A per-stream view over a [`SharedKvCache`] pool: the handle every
/// serving path holds. Carries the stream's own [`CacheStats`], its
/// cluster-id → content-key bindings, the pins it holds (released on drop),
/// any outstanding install reservations (aborted on drop, so waiters on
/// another thread never hang on an unwound stream), and any promotion
/// checkouts (buried on drop — an unwound stream never strands a host
/// handle).
///
/// [`KvCacheManager::new`] wraps a fresh private pool — single-stream
/// behaviour, metric-for-metric the PR 3 manager. [`shared_view`] attaches
/// to an existing pool for cross-stream sharing.
///
/// [`shared_view`]: KvCacheManager::shared_view
pub struct KvCacheManager<H> {
    shared: Arc<SharedKvCache<H>>,
    stream: u64,
    private: bool,
    /// cluster id → pool key (content hash when bound, view-salted id
    /// otherwise).
    binds: HashMap<usize, u64>,
    /// pool keys this view currently holds pins on — one entry-epoch per
    /// pin, so unpins always target the incarnation they actually pinned.
    held_pins: HashMap<u64, Vec<u64>>,
    /// pool keys this view holds install reservations for.
    reserved: Vec<u64>,
    /// host handles checked out by a [`Lookup::MustPromote`], waiting for
    /// the caller to [`take_promotion`](Self::take_promotion) them
    /// (key → (host handle, entry bytes)).
    promotions_out: HashMap<u64, (H, usize)>,
    /// archived payloads checked out by a [`Lookup::MustRecall`], waiting
    /// for the caller to [`take_recall`](Self::take_recall) them
    /// (key → (serialized KV bytes, entry bytes)). Plain bytes, no backend
    /// handle: dropping one loses the disk copy, nothing more.
    recalls_out: HashMap<u64, (Vec<u8>, usize)>,
    /// this stream's own counters (residency fields filled at `stats()`).
    view: CacheStats,
}

impl<H> Default for KvCacheManager<H> {
    fn default() -> Self {
        Self::new(CachePolicy::default())
    }
}

impl<H> KvCacheManager<H> {
    /// A view over a fresh private pool: exactly the single-stream manager
    /// the serial serving paths have always used.
    pub fn new(policy: CachePolicy) -> Self {
        Self::view_over(Arc::new(SharedKvCache::new(policy)), true)
    }

    /// A view over an existing shared pool (one per concurrent stream).
    pub fn shared_view(shared: &Arc<SharedKvCache<H>>) -> Self {
        Self::view_over(Arc::clone(shared), false)
    }

    fn view_over(shared: Arc<SharedKvCache<H>>, private: bool) -> Self {
        let stream = shared.register_stream();
        KvCacheManager {
            shared,
            stream,
            private,
            binds: HashMap::new(),
            held_pins: HashMap::new(),
            reserved: Vec::new(),
            promotions_out: HashMap::new(),
            recalls_out: HashMap::new(),
            view: CacheStats::default(),
        }
    }

    pub fn policy(&self) -> CachePolicy {
        self.shared.policy()
    }

    /// Stream id of this view (diagnostics; unique per pool).
    pub fn stream_id(&self) -> u64 {
        self.stream
    }

    pub fn is_shared(&self) -> bool {
        !self.private
    }

    /// The pool this view is attached to (for pool-level stats/drain).
    pub fn pool(&self) -> &Arc<SharedKvCache<H>> {
        &self.shared
    }

    /// View-salted fallback key: unique per (view, cluster), so unbound
    /// clusters behave exactly like PR 3's per-stream-private entries.
    fn private_key(&self, cluster_id: usize) -> u64 {
        // splitmix of the (stream, cluster) pair; streams are unique per
        // pool so two views can never collide on a fallback key.
        crate::util::rng::splitmix64(
            self.stream
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(cluster_id as u64)
                .wrapping_add(0xD1B54A32D192ED03),
        )
    }

    /// Key for a cluster id without memoizing (for `&self` accessors).
    fn key_of(&self, cluster_id: usize) -> u64 {
        self.binds.get(&cluster_id).copied().unwrap_or_else(|| self.private_key(cluster_id))
    }

    /// Key for a cluster id, memoized so `resident_clusters` can invert it.
    fn key_for(&mut self, cluster_id: usize) -> u64 {
        let fallback = self.private_key(cluster_id);
        *self.binds.entry(cluster_id).or_insert(fallback)
    }

    /// Bind a cluster to its representative's content key, enabling
    /// cross-stream sharing for it. Only meaningful on shared views —
    /// private views keep PR 3's per-cluster-private behaviour (a no-op
    /// here), so single-stream serving stays metric-for-metric identical
    /// to the serial path. Must be called before the cluster's first
    /// lookup; rebinding an already-bound cluster is a bug.
    pub fn bind(&mut self, cluster_id: usize, key: RepKey) {
        if self.private {
            return;
        }
        let prev = self.binds.insert(cluster_id, key.0);
        debug_assert!(prev.is_none() || prev == Some(key.0),
                      "cluster {cluster_id} rebound to a different key");
    }

    fn note_pin(&mut self, key: u64, epoch: u64) {
        self.held_pins.entry(key).or_default().push(epoch);
    }

    /// Bury an unconsumed promotion checkout for `key`, if any (fresh
    /// install superseded it, or the caller aborted).
    fn bury_checkout(&mut self, key: u64) {
        if let Some((stale, _)) = self.promotions_out.remove(&key) {
            self.shared.bury(stale);
        }
    }

    /// Look up the cluster's entry. A device hit refreshes LRU, records
    /// the stream's hit stats, and takes one pin for the caller. A
    /// host-tier hit ([`Lookup::MustPromote`]) checks the host handle out
    /// — take it with [`take_promotion`](Self::take_promotion), copy it
    /// back up, and [`install_promoted`](Self::install_promoted). A miss
    /// reserves the key: the caller must [`install`](Self::install) or
    /// [`abort_install`](Self::abort_install). Blocks while another stream
    /// installs the same key, then hits the fresh entry — the single-flight
    /// discipline that makes N racing streams pay one prefill (or one
    /// promotion copy).
    pub fn lookup(&mut self, cluster_id: usize) -> Lookup {
        let key = self.key_for(cluster_id);
        match self.shared.lookup_or_reserve(self.stream, key) {
            Found::Hit { bytes, shared, epoch } => {
                self.note_pin(key, epoch);
                self.view.hits += 1;
                self.view.bytes_saved += bytes as u64;
                if shared {
                    self.view.shared_hits += 1;
                    self.view.dedup_bytes_saved += bytes as u64;
                }
                Lookup::Hit
            }
            Found::Promote { handle, bytes } => {
                self.view.misses += 1;
                self.view.host_hits += 1;
                self.reserved.push(key);
                self.promotions_out.insert(key, (handle, bytes));
                Lookup::MustPromote
            }
            Found::Recall { payload, bytes } => {
                self.view.misses += 1;
                self.view.disk_hits += 1;
                self.reserved.push(key);
                self.recalls_out.insert(key, (payload, bytes));
                Lookup::MustRecall
            }
            Found::Reserved => {
                self.view.misses += 1;
                self.reserved.push(key);
                Lookup::MustInstall
            }
        }
    }

    /// The host handle (and entry bytes) checked out by this cluster's
    /// [`Lookup::MustPromote`]. The caller owns the returned handle: copy
    /// it back up (`Backend::promote_kv` — the backend consumes the host
    /// copy on success) and [`install_promoted`](Self::install_promoted)
    /// the device handle, or [`abort_install`](Self::abort_install) on
    /// failure after releasing the host handle to the backend.
    pub fn take_promotion(&mut self, cluster_id: usize) -> Option<(H, usize)> {
        let key = self.key_of(cluster_id);
        self.promotions_out.remove(&key)
    }

    /// The archived payload (and entry bytes) checked out by this
    /// cluster's [`Lookup::MustRecall`]. The caller deserializes it
    /// (`Backend::recall_kv` → a host handle), copies it up
    /// (`Backend::promote_kv`), and completes with
    /// [`install_recalled`](Self::install_recalled); on any failure it
    /// falls through to a repaid prefill under the still-held reservation
    /// — the disk record was consumed at checkout, so there is nothing to
    /// put back.
    pub fn take_recall(&mut self, cluster_id: usize) -> Option<(Vec<u8>, usize)> {
        let key = self.key_of(cluster_id);
        self.recalls_out.remove(&key)
    }

    /// Shared implementation of the install family.
    fn admit(&mut self, cluster_id: usize, handle: H, bytes: usize, kind: Admit) -> TieredOut<H> {
        let key = self.key_for(cluster_id);
        self.reserved.retain(|&k| k != key);
        // an unconsumed promotion checkout for this key is superseded by
        // the fresh install: bury it (it surfaces at the next drain). This
        // is the graceful path for callers that answered MustPromote with
        // a plain prefill install. An unconsumed recall checkout is plain
        // bytes — dropped on the spot.
        self.bury_checkout(key);
        self.recalls_out.remove(&key);
        let got = self.shared.install(self.stream, key, handle, bytes, kind);
        self.note_pin(key, got.epoch);
        match kind {
            Admit::Prefill => self.view.prefills += 1,
            Admit::Promote => self.view.promotions += 1,
            Admit::Recall => self.view.recalls += 1,
        }
        self.view.evictions += got.evictions;
        // only `got.out` is handed back for disposal; demotion work items
        // leave "for use" and are not counted released (here or pool-side).
        self.view.released += got.out.len() as u64;
        TieredOut { release: got.out, demote: got.demote }
    }

    /// Install the KV cache of `cluster_id`'s representative, fulfilling
    /// the reservation its `lookup` miss took (reservation-free installs —
    /// the in-batch pipeline's pattern — are also fine). The entry is
    /// admitted with one pin held by this view. Returns every handle the
    /// caller must release on the engine: budget evictions, a replaced
    /// same-key entry, the rejected new handle itself if a pinned resident
    /// won the race, and any deferred-release backlog. **Compat wrapper**:
    /// with the host tier enabled, demotion work items are flattened into
    /// plain releases (the host-tier opportunity is dropped) — tier-aware
    /// callers use [`install_tiered`](Self::install_tiered).
    pub fn install(&mut self, cluster_id: usize, handle: H, bytes: usize) -> Vec<H> {
        self.install_tiered(cluster_id, handle, bytes).into_release_all()
    }

    /// Tier-aware install: like [`install`](Self::install), but budget
    /// victims come back as [`Demotion`] work items when the host tier is
    /// enabled. The caller demotes each (`Backend::demote_kv`) and
    /// completes with [`admit_host`](Self::admit_host).
    pub fn install_tiered(&mut self, cluster_id: usize, handle: H, bytes: usize) -> TieredOut<H> {
        self.admit(cluster_id, handle, bytes, Admit::Prefill)
    }

    /// Complete a promotion: install the device handle produced by copying
    /// a checked-out host entry back up. Identical admission semantics to
    /// [`install_tiered`](Self::install_tiered), but the pool counts a
    /// `promotion` instead of a `prefill` — the stream repaid a copy, not
    /// a prefill.
    pub fn install_promoted(&mut self, cluster_id: usize, handle: H, bytes: usize) -> TieredOut<H> {
        self.admit(cluster_id, handle, bytes, Admit::Promote)
    }

    /// Complete a recall: install the device handle produced by walking a
    /// checked-out archive payload disk → host → device. Identical
    /// admission semantics to [`install_tiered`](Self::install_tiered),
    /// but the pool counts a `recall` — the stream repaid a disk read plus
    /// a copy, not a prefill.
    pub fn install_recalled(&mut self, cluster_id: usize, handle: H, bytes: usize) -> TieredOut<H> {
        self.admit(cluster_id, handle, bytes, Admit::Recall)
    }

    /// Complete a demotion: hand the host copy of `slot`'s entry to the
    /// pool. Returns the tiered work the admission forced: handles to
    /// release (LRU host-tier deaths under a disabled disk tier, or the
    /// now-redundant copy itself if the key became resident again while
    /// the copy was in flight) and [`Archival`] spills to carry to disk
    /// (`Backend::archive_kv` then [`admit_disk`](Self::admit_disk)).
    pub fn admit_host(&mut self, slot: HostSlot, host: H) -> HostAdmit<H> {
        let (out, admitted) = self.shared.admit_host(slot, host);
        if admitted {
            self.view.demotions += 1;
        }
        self.view.released += out.release.len() as u64;
        out
    }

    /// Complete an archival: hand the serialized payload of an
    /// [`Archival`]'s entry to the disk tier. Returns whether the record
    /// was admitted (counted as an `archived` on this view); a dropped
    /// record (tier off, oversized, key live again, I/O error) is just a
    /// lost caching opportunity.
    pub fn admit_disk(&mut self, slot: DiskSlot, payload: &[u8]) -> bool {
        let admitted = self.shared.admit_disk(slot, payload);
        if admitted {
            self.view.archived += 1;
        }
        admitted
    }

    /// Cancel this view's install reservation of a cluster (error paths;
    /// dropping the view aborts all of them). An unconsumed promotion
    /// checkout is buried — waiters wake, find both tiers empty, and
    /// re-race a fresh prefill.
    pub fn abort_install(&mut self, cluster_id: usize) {
        let key = self.key_of(cluster_id);
        self.bury_checkout(key);
        self.recalls_out.remove(&key);
        if let Some(i) = self.reserved.iter().position(|&k| k == key) {
            self.reserved.swap_remove(i);
            self.shared.abort_install(self.stream, key);
        }
    }

    /// Borrow the resident handle under the shard lock. Keep `f` short and
    /// non-blocking: enqueueing a backend submit is the intended use. The
    /// caller should hold a pin (lookup/install) so the entry cannot vanish
    /// between its hit and this access.
    pub fn with_handle<R>(&self, cluster_id: usize, f: impl FnOnce(&H) -> R) -> Option<R> {
        self.shared.with_handle(self.key_of(cluster_id), f)
    }

    /// Non-mutating device-residency probe (no stats, no LRU refresh).
    pub fn contains(&self, cluster_id: usize) -> bool {
        self.shared.contains(self.key_of(cluster_id))
    }

    /// Non-mutating host-tier probe (no stats, no LRU refresh, no
    /// checkout).
    pub fn contains_host(&self, cluster_id: usize) -> bool {
        self.shared.contains_host(self.key_of(cluster_id))
    }

    /// Protect a resident entry from eviction (pins nest, and count toward
    /// the global pin total). Returns false if the cluster is not resident.
    pub fn pin(&mut self, cluster_id: usize) -> bool {
        let key = self.key_for(cluster_id);
        if let Some(epoch) = self.shared.pin(key) {
            self.note_pin(key, epoch);
            true
        } else {
            false
        }
    }

    /// Drop one pin *this view holds*. Returns false if the view holds none
    /// for the cluster — a view can never unpin another stream's pin. A pin
    /// orphaned by a quarantine (its entry incarnation is gone) resolves as
    /// a pool-side no-op but still balances this view's bookkeeping.
    pub fn unpin(&mut self, cluster_id: usize) -> bool {
        let key = self.key_of(cluster_id);
        let Some(epochs) = self.held_pins.get_mut(&key) else {
            return false;
        };
        let Some(epoch) = epochs.pop() else {
            return false;
        };
        if epochs.is_empty() {
            self.held_pins.remove(&key);
        }
        self.shared.unpin(key, epoch)
    }

    /// Whether ANY stream currently pins the cluster's entry.
    pub fn is_pinned(&self, cluster_id: usize) -> bool {
        self.pin_count(cluster_id) > 0
    }

    /// Global pin count of the cluster's entry (0 when absent): the sum of
    /// every stream's pins, which is what eviction/TTL safety needs. Under
    /// pipelined serving pins are the lifetime anchor for in-flight engine
    /// tickets: a cluster is pinned from before its prefill/extend ticket
    /// is submitted until after `wait` returns, so no concurrent admission,
    /// sweep, or other stream can release an entry the device still reads.
    pub fn pin_count(&self, cluster_id: usize) -> u32 {
        self.shared.pin_count(self.key_of(cluster_id))
    }

    /// Pins this view itself holds on the cluster's entry.
    pub fn own_pin_count(&self, cluster_id: usize) -> u32 {
        self.held_pins
            .get(&self.key_of(cluster_id))
            .map(|epochs| epochs.len() as u32)
            .unwrap_or(0)
    }

    /// Invalidate every **device** pool entry whose handle the predicate
    /// marks stale — in serving, `|h| !backend.kv_current(h)` after a
    /// [`BackendError::LaneDead`]. Host-tier copies are never swept: they
    /// survive the lane death and re-promote instead of repaying the
    /// prefill. Removed entries' handles come back for bookkeeping
    /// release; pins any view held on them (including this one's) become
    /// orphans whose unpins are no-ops, so callers should still unpin to
    /// balance their own accounting. See the module docs' quarantine
    /// contract.
    ///
    /// [`BackendError::LaneDead`]: crate::runtime::BackendError::LaneDead
    pub fn quarantine_stale(&mut self, is_stale: impl FnMut(&H) -> bool) -> Vec<H> {
        let (out, quarantined) = self.shared.quarantine_stale(is_stale);
        self.view.quarantined += quarantined;
        self.view.released += out.len() as u64;
        out
    }

    /// Release one cluster's entry (TTL sweeps). Unpinned: handles come
    /// back now (a host-tier copy of the key dies with it). Pinned by any
    /// stream: deferred — the entry is doomed and its handle surfaces
    /// through a later drain. Either way the returned vector includes any
    /// deferred-release backlog that became safe.
    pub fn release(&mut self, cluster_id: usize) -> Vec<H> {
        let key = self.key_of(cluster_id);
        let (out, deferred) = self.shared.release(key);
        if deferred {
            self.view.deferred_releases += 1;
        }
        self.view.released += out.len() as u64;
        out
    }

    /// TTL-expire this stream's interest in a cluster. On a private view
    /// the entry is released now (the serial PR 3 semantics). On a shared
    /// view the entry may be another stream's warm hit — one stream's
    /// cluster staleness says nothing about the pool-global recency the
    /// entry's LRU position tracks — so only this stream's binding is
    /// dropped: the content stays resident for the fleet, and reclamation
    /// belongs to the byte budget (LRU at install) and the end-of-run
    /// drain. Re-opening a same-content cluster later simply re-binds the
    /// key and hits the still-warm entry. Call only when the view holds no
    /// pins for the cluster (the TTL sweep's pin check guarantees this —
    /// pins are tracked by key, so even a misuse is cleaned up by drop).
    pub fn expire(&mut self, cluster_id: usize) -> Vec<H> {
        if self.private {
            self.release(cluster_id)
        } else {
            self.binds.remove(&cluster_id);
            Vec::new()
        }
    }

    /// End-of-stream cleanup. Private view: drain the whole pool (the
    /// serial paths' behaviour), both tiers, pinned or not. Shared view:
    /// drop only this stream's pins, reservations, and checkouts — other
    /// streams' entries stay warm — and return any deferred handles that
    /// became safe; the pool owner drains the rest via
    /// [`SharedKvCache::drain_all`] once every stream is done.
    pub fn release_all(&mut self) -> Vec<H> {
        self.drop_holds();
        let out = if self.private {
            self.shared.drain_all()
        } else {
            self.shared.collect_deferred()
        };
        self.view.released += out.len() as u64;
        out
    }

    /// Abort reservations, bury promotion checkouts, and drop held pins
    /// (shared Drop/cleanup path).
    fn drop_holds(&mut self) {
        for (_, (handle, _)) in std::mem::take(&mut self.promotions_out) {
            self.shared.bury(handle);
        }
        // recall checkouts are plain bytes, already consumed from disk:
        // dropping them loses nothing but the cached copy.
        self.recalls_out.clear();
        for key in std::mem::take(&mut self.reserved) {
            self.shared.abort_install(self.stream, key);
        }
        for (key, epochs) in std::mem::take(&mut self.held_pins) {
            for epoch in epochs {
                self.shared.unpin(key, epoch);
            }
        }
    }

    /// Device-resident entries in the underlying pool (all streams').
    pub fn len(&self) -> usize {
        self.shared.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shared.is_empty()
    }

    pub fn resident_bytes(&self) -> usize {
        self.shared.resident_bytes()
    }

    /// This view's resident cluster ids, sorted (deterministic for tests).
    pub fn resident_clusters(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self
            .binds
            .iter()
            .filter(|(_, &key)| self.shared.contains(key))
            .map(|(&cid, _)| cid)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// This stream's accounting, with pool-level residency: `hits`/
    /// `misses`/`prefills`/`evictions`/`released`/`bytes_saved` (the
    /// `shared_hits`/`dedup_bytes_saved` cross-stream split and the
    /// `demotions`/`promotions`/`host_hits`/`archived`/`recalls`/
    /// `disk_hits` tier counters) count this view's own operations;
    /// `resident_bytes`/`peak_bytes`/`host_bytes`/`disk_bytes` snapshot
    /// the pool. For a private view the two coincide with the pool totals.
    pub fn stats(&self) -> CacheStats {
        let pool = self.shared.stats();
        CacheStats {
            resident_bytes: pool.resident_bytes,
            peak_bytes: pool.peak_bytes,
            host_bytes: pool.host_bytes,
            disk_bytes: pool.disk_bytes,
            ..self.view
        }
    }
}

impl<H> Drop for KvCacheManager<H> {
    /// A view dropped mid-error must not strand other streams: outstanding
    /// install reservations are aborted (waiters wake and re-race),
    /// promotion checkouts are buried (the host handle surfaces at the
    /// next drain), recall checkouts are dropped (plain bytes), and this
    /// stream's pins are dropped (its in-flight tickets are dead by now). Handles the pool still holds are NOT
    /// drained here — the serve paths drain on success via
    /// `release_all`/`drain_all`; after an unwind the pool's handles are
    /// engine-owned ids the engine reclaims at shutdown (a bounded leak,
    /// not corruption).
    fn drop(&mut self) {
        self.drop_holds();
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn unbounded<H>() -> KvCacheManager<H> {
        KvCacheManager::new(CachePolicy::unbounded())
    }

    /// install used to return with the caller holding exactly one pin.
    fn serve_install(m: &mut KvCacheManager<u32>, cid: usize, h: u32, bytes: usize)
                     -> Vec<u32> {
        // serving paths reserve via a lookup miss first; tests that install
        // blind (the in-batch pipeline pattern) call m.install directly.
        assert!(!m.lookup(cid).is_hit(), "expected a miss for cid {cid}");
        m.install(cid, h, bytes)
    }

    #[test]
    fn install_lookup_release_cycle() {
        let mut m: KvCacheManager<u32> = unbounded();
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        assert!(m.install(0, 111, 1024).is_empty());
        assert!(m.lookup(0).is_hit());
        assert!(m.lookup(0).is_hit());
        assert_eq!(m.lookup(1), Lookup::MustInstall); // other cluster: miss
        m.abort_install(1);
        assert_eq!(m.with_handle(0, |h| *h), Some(111));
        assert_eq!(m.resident_clusters(), vec![0]);
        // 3 pins held: install + two lookup hits
        assert_eq!(m.own_pin_count(0), 3);
        for _ in 0..3 {
            assert!(m.unpin(0));
        }
        assert_eq!(m.release(0), vec![111]);
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        m.abort_install(0);
        let s = m.stats();
        assert_eq!((s.prefills, s.hits, s.misses, s.released), (1, 2, 3, 1));
        assert_eq!(s.bytes_saved, 2 * 1024);
        assert_eq!(s.shared_hits, 0, "a private view never counts shared hits");
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.peak_bytes, 1024);
        assert!((s.hit_rate() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn multiple_residents_under_budget() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(1000, 8));
        for cid in 0..3 {
            assert!(serve_install(&mut m, cid, cid as u32, 100).is_empty());
            m.unpin(cid);
        }
        assert_eq!(m.len(), 3);
        for cid in 0..3 {
            assert!(m.lookup(cid).is_hit());
            assert_eq!(m.with_handle(cid, |h| *h), Some(cid as u32));
            m.unpin(cid);
        }
        assert_eq!(m.resident_bytes(), 300);
        let drained = m.release_all();
        assert_eq!(drained.len(), 3);
    }

    #[test]
    fn lru_eviction_under_entry_budget() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(usize::MAX, 2));
        serve_install(&mut m, 0, 10, 1);
        m.unpin(0);
        serve_install(&mut m, 1, 11, 1);
        m.unpin(1);
        assert!(m.lookup(0).is_hit()); // 0 now more recently used than 1
        m.unpin(0);
        let evicted = serve_install(&mut m, 2, 12, 1);
        assert_eq!(evicted, vec![11], "LRU entry (cluster 1) must go first");
        assert_eq!(m.resident_clusters(), vec![0, 2]);
        m.unpin(2);
        m.release_all();
    }

    #[test]
    fn byte_budget_evicts_down() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(250, 8));
        serve_install(&mut m, 0, 10, 100);
        m.unpin(0);
        serve_install(&mut m, 1, 11, 100);
        m.unpin(1);
        // 100 + 100 + 100 > 250: the oldest unpinned entry falls out until
        // the budget holds again.
        let evicted = serve_install(&mut m, 2, 12, 100);
        assert_eq!(evicted, vec![10]);
        assert_eq!(m.resident_bytes(), 200);
        m.unpin(2);
        m.release_all();
    }

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(usize::MAX, 1));
        serve_install(&mut m, 0, 10, 1); // still pinned (in-flight)
        let evicted = serve_install(&mut m, 1, 11, 1);
        assert!(evicted.is_empty(), "pinned cluster 0 must not be evicted");
        assert_eq!(m.len(), 2, "over budget rather than evict pinned");
        m.unpin(0);
        // next admission can now reclaim cluster 0
        let evicted = serve_install(&mut m, 2, 12, 1);
        assert_eq!(evicted, vec![10]);
        m.unpin(1);
        m.unpin(2);
        assert_eq!(m.release_all().len(), 2);
    }

    #[test]
    fn single_resident_policy_degenerates_to_seed() {
        // max_entries = 1 with unpin-before-next-install reproduces the
        // seed's one-slot behaviour: each install evicts the previous.
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::single_resident());
        serve_install(&mut m, 0, 1, 10);
        m.unpin(0);
        let evicted = serve_install(&mut m, 1, 2, 20);
        assert_eq!(evicted, vec![1]);
        assert_eq!(m.resident_clusters(), vec![1]);
        assert_eq!(m.stats().peak_bytes, 30); // both resident inside install
        m.unpin(1);
        m.release_all();
    }

    #[test]
    fn reinstall_replaces_and_returns_old_handle() {
        let mut m: KvCacheManager<u32> = unbounded();
        m.install(0, 1, 10);
        m.unpin(0);
        let evicted = m.install(0, 2, 20);
        assert_eq!(evicted, vec![1]);
        assert!(m.lookup(0).is_hit());
        assert_eq!(m.with_handle(0, |h| *h), Some(2));
        assert_eq!(m.resident_bytes(), 20);
        m.unpin(0);
        m.unpin(0);
        m.release_all();
    }

    #[test]
    fn reinstall_over_pinned_cluster_rejects_new_handle() {
        // An in-flight (pinned) entry may be mid-extend: a racing duplicate
        // install must not evict it. The new handle comes straight back,
        // and the caller still ends up holding a pin (so its unpin at
        // finalize balances).
        let mut m: KvCacheManager<u32> = unbounded();
        m.install(0, 1, 10); // still pinned
        let returned = m.install(0, 2, 20);
        assert_eq!(returned, vec![2], "new handle rejected, not the resident one");
        assert_eq!(m.with_handle(0, |h| *h), Some(1), "in-flight entry survives");
        assert_eq!(m.resident_bytes(), 10);
        assert_eq!(m.stats().evictions, 0);
        assert_eq!(m.pin_count(0), 2, "rejecting install still pins for its caller");
        m.unpin(0);
        m.unpin(0);
        m.release_all();
    }

    #[test]
    fn release_of_pinned_entry_is_deferred_until_last_unpin() {
        // The cross-stream hazard the doomed flag exists for, in one view:
        // a release while pinned must NOT return the handle (the device may
        // still read it); it surfaces at the next drain after the last
        // unpin.
        let mut m: KvCacheManager<u32> = unbounded();
        m.install(0, 7, 10); // pinned (in-flight)
        assert!(m.release(0).is_empty(), "pinned release defers the handle");
        assert_eq!(m.stats().deferred_releases, 1);
        assert!(m.contains(0), "doomed entry stays resident while pinned");
        assert!(m.unpin(0));
        assert!(!m.contains(0), "last unpin reclaims the doomed entry");
        assert_eq!(m.resident_bytes(), 0);
        let drained = m.release_all();
        assert_eq!(drained, vec![7], "handle surfaces exactly once, at the drain");
    }

    #[test]
    fn doomed_entry_resurrected_by_a_hit() {
        let mut m: KvCacheManager<u32> = unbounded();
        m.install(0, 7, 10);
        assert!(m.release(0).is_empty()); // doomed (install pin still held)
        assert!(m.lookup(0).is_hit(), "a hit resurrects the doomed entry");
        m.unpin(0); // lookup pin
        m.unpin(0); // install pin
        assert!(m.contains(0), "resurrected entry survives its last unpin");
        assert_eq!(m.release(0), vec![7]);
    }

    #[test]
    fn doomed_entry_resurrected_by_a_racing_install() {
        // install over a pinned doomed entry re-demands its content: like a
        // lookup hit, it must clear the doom — the caller would otherwise
        // hold a pin on an entry scheduled to die under it.
        let mut m: KvCacheManager<u32> = unbounded();
        m.install(0, 7, 10); // pinned
        assert!(m.release(0).is_empty()); // doomed
        let returned = m.install(0, 8, 10); // rejected, but resurrects
        assert_eq!(returned, vec![8]);
        m.unpin(0); // first install's pin
        m.unpin(0); // second install's pin
        assert!(m.contains(0), "re-demanded entry survives its last unpin");
        assert_eq!(m.release(0), vec![7]);
    }

    #[test]
    fn expire_on_shared_view_keeps_the_fleet_entry_warm() {
        // One stream's TTL staleness must not reclaim an entry another
        // stream is actively hitting: expire only drops the binding.
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded()));
        let mut a = KvCacheManager::shared_view(&pool);
        let mut b = KvCacheManager::shared_view(&pool);
        let key = RepKey::of_parts(["bb"], [4]);
        a.bind(0, key);
        b.bind(0, key);
        assert_eq!(a.lookup(0), Lookup::MustInstall);
        a.install(0, 5, 10);
        a.unpin(0);
        assert!(a.expire(0).is_empty(), "shared expiry returns no handles");
        assert!(b.lookup(0).is_hit(), "B keeps hitting the warm entry");
        b.unpin(0);
        // A re-opens a same-content cluster later: re-bind, still warm.
        a.bind(3, key);
        assert!(a.lookup(3).is_hit());
        a.unpin(3);
        assert_eq!(pool.stats().prefills, 1, "expiry never forced a re-prefill");
        assert_eq!(pool.drain_all(), vec![5]);

        // a PRIVATE view's expire keeps the serial release-now semantics.
        let mut p: KvCacheManager<u32> = unbounded();
        p.install(0, 9, 10);
        p.unpin(0);
        assert_eq!(p.expire(0), vec![9]);
    }

    #[test]
    fn blind_install_resolves_a_foreign_reservation() {
        // The in-batch pipeline installs without a reservation; if another
        // stream holds one for the same key, the install must resolve it —
        // a pending entry may never shadow a resident key (the invariant
        // `consistent()` checks), and the reserving stream's own install
        // then lands on the resident branch.
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded()));
        let mut a = KvCacheManager::shared_view(&pool);
        let mut b = KvCacheManager::shared_view(&pool);
        let key = RepKey::of_parts(["bb"], [6]);
        a.bind(0, key);
        b.bind(0, key);
        assert_eq!(a.lookup(0), Lookup::MustInstall); // A holds the reservation
        let out = b.install(0, 21, 10); // B installs blind
        assert!(out.is_empty());
        assert!(pool.consistent(), "pending must not shadow the resident key");
        // A's install (it was mid-"prefill") lands on the pinned resident:
        // its handle comes straight back and A still ends up pinned.
        let returned = a.install(0, 22, 10);
        assert_eq!(returned, vec![22]);
        assert_eq!(a.pin_count(0), 2);
        a.unpin(0);
        b.unpin(0);
        assert_eq!(pool.stats().prefills, 2, "both installs count as paid prefills");
        assert_eq!(pool.drain_all(), vec![21]);
    }

    #[test]
    fn budget_property_never_exceeded() {
        // After every install: within budget, unless only pinned entries
        // remain (eviction refuses to touch in-flight clusters).
        prop_check(150, |rng| {
            let policy = CachePolicy::new(rng.range(50, 400), rng.range(1, 5));
            let mut m: KvCacheManager<u64> = KvCacheManager::new(policy);
            let mut next = 0u64;
            for _ in 0..rng.range(1, 30) {
                let cid = rng.below(6);
                if m.contains(cid) {
                    m.unpin(cid);
                    continue;
                }
                let h = next;
                next += 1;
                if !m.lookup(cid).is_hit() {
                    m.install(cid, h, rng.range(1, 120));
                }
                // the invariant holds at install time (eviction only runs
                // there): within budget, or nothing evictable remains.
                // It must be checked BEFORE the coin-flip unpin below —
                // unpinning never triggers eviction, so an over-budget
                // pinned admission legitimately stays over once unpinned,
                // until the next install reclaims it.
                assert!(
                    m.pool().budget_ok(),
                    "over budget with evictable entries: {} bytes / {} entries",
                    m.resident_bytes(),
                    m.len()
                );
                if rng.below(2) == 0 {
                    m.unpin(cid);
                }
            }
            m.release_all();
        });
    }

    #[test]
    fn pinned_never_evicted_property() {
        prop_check(150, |rng| {
            let policy = CachePolicy::new(rng.range(50, 300), rng.range(1, 4));
            let mut m: KvCacheManager<u64> = KvCacheManager::new(policy);
            let mut pinned: Vec<usize> = Vec::new(); // model of in-flight ids
            let mut next = 0u64;
            for _ in 0..rng.range(1, 40) {
                match rng.below(3) {
                    0 => {
                        let cid = rng.below(8);
                        if !m.contains(cid) {
                            let h = next;
                            next += 1;
                            if !m.lookup(cid).is_hit() {
                                m.install(cid, h, rng.range(1, 100));
                                pinned.push(cid);
                            }
                        }
                    }
                    1 => {
                        if !pinned.is_empty() {
                            let i = rng.below(pinned.len());
                            let cid = pinned.swap_remove(i);
                            assert!(m.unpin(cid));
                        }
                    }
                    _ => {
                        let cid = rng.below(8);
                        if m.lookup(cid).is_hit() {
                            m.unpin(cid); // probe only: release the hit pin
                        } else {
                            m.abort_install(cid);
                        }
                    }
                }
                for &cid in &pinned {
                    assert!(m.contains(cid), "pinned cluster {cid} was evicted");
                    assert!(m.is_pinned(cid));
                }
            }
            m.release_all();
        });
    }

    #[test]
    fn every_handle_returned_exactly_once_property() {
        // Handle conservation at multi-resident scale: handles installed
        // minus handles returned == handles resident, and nothing is ever
        // returned twice — now including the doomed/deferred path.
        prop_check(150, |rng| {
            let policy = CachePolicy::new(rng.range(20, 200), rng.range(1, 4));
            let mut m: KvCacheManager<u64> = KvCacheManager::new(policy);
            let mut live: Vec<u64> = Vec::new(); // handles we must get back
            let mut returned: Vec<u64> = Vec::new();
            let take = |hs: Vec<u64>, live: &mut Vec<u64>, ret: &mut Vec<u64>| {
                for h in hs {
                    assert!(live.contains(&h), "returned unknown handle {h}");
                    assert!(!ret.contains(&h), "handle {h} returned twice");
                    live.retain(|&x| x != h);
                    ret.push(h);
                }
            };
            let mut next = 0u64;
            for _ in 0..rng.range(1, 40) {
                match rng.below(5) {
                    0 | 1 => {
                        let cid = rng.below(6);
                        if !m.contains(cid) {
                            let h = next;
                            next += 1;
                            if m.lookup(cid).is_hit() {
                                m.unpin(cid);
                            } else {
                                live.push(h);
                                let evicted = m.install(cid, h, rng.range(1, 80));
                                take(evicted, &mut live, &mut returned);
                                m.unpin(cid);
                            }
                        }
                    }
                    2 => {
                        let cid = rng.below(6);
                        if m.lookup(cid).is_hit() {
                            m.unpin(cid);
                        } else {
                            m.abort_install(cid);
                        }
                    }
                    3 => {
                        let out = m.release(rng.below(6));
                        take(out, &mut live, &mut returned);
                    }
                    _ => {
                        let drained = m.release_all();
                        take(drained, &mut live, &mut returned);
                    }
                }
                assert_eq!(live.len(), m.len(), "live model diverged from cache");
            }
            let drained = m.release_all();
            take(drained, &mut live, &mut returned);
            assert!(live.is_empty(), "leaked handles: {live:?}");
            assert_eq!(m.stats().resident_bytes, 0);
        });
    }

    #[test]
    fn nested_pins_cover_overlapping_tickets() {
        // Two in-flight tickets on the same cluster (e.g. a warm hit's
        // extend submitted while the install pin is still held) must stack:
        // the entry survives budget pressure until the LAST ticket unpins.
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(usize::MAX, 1));
        serve_install(&mut m, 0, 10, 1); // ticket 1 (install pin)
        assert_eq!(m.pin_count(0), 1);
        assert!(m.pin(0)); // ticket 2
        assert_eq!(m.pin_count(0), 2);
        m.unpin(0); // ticket 1 completes
        assert_eq!(m.pin_count(0), 1);
        let evicted = serve_install(&mut m, 1, 11, 1); // budget pressure: still pinned
        assert!(evicted.is_empty(), "cluster with a live ticket must survive");
        assert!(m.contains(0));
        m.unpin(0); // ticket 2 completes
        assert_eq!(m.pin_count(0), 0);
        let evicted = serve_install(&mut m, 2, 12, 1);
        assert_eq!(evicted, vec![10], "unpinned entry finally reclaimable");
        assert_eq!(m.pin_count(99), 0, "absent cluster has no pins");
        m.unpin(1);
        m.unpin(2);
        m.release_all();
    }

    #[test]
    fn stats_peak_monotone() {
        let mut m: KvCacheManager<()> = unbounded();
        m.install(0, (), 100);
        m.unpin(0);
        m.release(0);
        m.install(1, (), 50);
        assert_eq!(m.stats().peak_bytes, 100);
        assert_eq!(m.stats().resident_bytes, 50);
        m.unpin(1);
        m.release(1);
    }

    // -- cross-view (shared pool) unit tests --------------------------------

    #[test]
    fn two_views_share_one_entry_by_content_key() {
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded()));
        let mut a = KvCacheManager::shared_view(&pool);
        let mut b = KvCacheManager::shared_view(&pool);
        let key = RepKey::of_parts(["backbone", "graph"], [1, 2, 3]);
        a.bind(0, key);
        b.bind(5, key); // different local cluster id, same content

        assert_eq!(a.lookup(0), Lookup::MustInstall);
        assert!(a.install(0, 42, 100).is_empty());
        assert!(b.lookup(5).is_hit(), "B reuses A's entry via the content key");
        assert_eq!(b.with_handle(5, |h| *h), Some(42));
        assert_eq!(pool.stats().prefills, 1, "one prefill across both streams");
        assert_eq!(b.stats().shared_hits, 1);
        assert_eq!(b.stats().dedup_bytes_saved, 100);
        assert_eq!(a.stats().shared_hits, 0, "the installer's own hits aren't shared");

        a.unpin(0);
        b.unpin(5);
        assert!(a.release_all().is_empty(), "shared views never drain the pool");
        assert!(b.release_all().is_empty());
        assert_eq!(pool.drain_all(), vec![42]);
        assert_eq!(pool.stats().resident_bytes, 0);
    }

    #[test]
    fn unbound_clusters_stay_private_between_views() {
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded()));
        let mut a = KvCacheManager::shared_view(&pool);
        let mut b = KvCacheManager::shared_view(&pool);
        assert_eq!(a.lookup(0), Lookup::MustInstall);
        a.install(0, 1, 10);
        assert_eq!(b.lookup(0), Lookup::MustInstall,
                   "same cluster id without a bind must not collide");
        b.install(0, 2, 10);
        assert_eq!(pool.stats().prefills, 2);
        a.unpin(0);
        b.unpin(0);
        let mut drained = pool.drain_all();
        drained.sort_unstable();
        assert_eq!(drained, vec![1, 2]);
    }

    #[test]
    fn release_by_one_stream_defers_past_another_streams_pin() {
        // The satellite fix: stream A's TTL release of an entry stream B
        // still pins must defer the handle, and it must surface exactly
        // once after B unpins.
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded()));
        let mut a = KvCacheManager::shared_view(&pool);
        let mut b = KvCacheManager::shared_view(&pool);
        let key = RepKey::of_parts(["bb"], [9]);
        a.bind(0, key);
        b.bind(0, key);

        assert_eq!(a.lookup(0), Lookup::MustInstall);
        a.install(0, 77, 10);
        a.unpin(0);
        assert!(b.lookup(0).is_hit()); // B's in-flight pin

        assert!(a.release(0).is_empty(), "A's release must defer, not free");
        assert_eq!(a.stats().deferred_releases, 1);
        assert_eq!(b.pin_count(0), 1, "B's pin survives A's release");
        assert_eq!(b.with_handle(0, |h| *h), Some(77), "B's handle stays valid");

        assert!(b.unpin(0));
        let deferred = pool.collect_deferred();
        assert_eq!(deferred, vec![77], "handle surfaces once B is done");
        assert!(pool.collect_deferred().is_empty(), "and only once");
        assert_eq!(pool.stats().resident_bytes, 0);
    }

    #[test]
    fn quarantine_invalidates_stale_entries_and_orphans_foreign_pins() {
        // The lane-death recovery path end to end: handle 10 was minted by
        // a now-dead lane incarnation; handles >= 100 by the live one.
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded()));
        let mut a = KvCacheManager::shared_view(&pool);
        let mut b = KvCacheManager::shared_view(&pool);
        let key = RepKey::of_parts(["bb"], [1]);
        a.bind(0, key);
        b.bind(0, key);
        assert_eq!(a.lookup(0), Lookup::MustInstall);
        a.install(0, 10, 64); // A pinned
        assert!(b.lookup(0).is_hit()); // B pinned too

        // A discovers LaneDead: quarantine sweeps the pool, pinned or not.
        let dead = a.quarantine_stale(|&h| h < 100);
        assert_eq!(dead, vec![10], "stale handle comes back exactly once");
        assert!(!a.contains(0), "quarantined entry is gone");
        assert_eq!(a.stats().quarantined, 1);
        assert_eq!(pool.stats().quarantined, 1);
        assert_eq!(pool.resident_bytes(), 0);

        // A balances its own bookkeeping, then repays the prefill.
        assert!(a.unpin(0), "own orphaned pin still balances the view");
        assert_eq!(a.lookup(0), Lookup::MustInstall, "stale content must miss");
        assert!(a.install(0, 100, 64).is_empty());

        // B's pin was taken on the DEAD incarnation: unpinning it must not
        // touch the fresh entry A's in-flight ticket depends on.
        assert!(b.unpin(0));
        assert_eq!(a.pin_count(0), 1, "orphaned unpin must not strip the fresh pin");
        assert!(b.lookup(0).is_hit(), "B rejoins on the repaid entry");
        assert_eq!(b.with_handle(0, |h| *h), Some(100));
        b.unpin(0);
        a.unpin(0);
        assert!(pool.consistent());
        assert_eq!(pool.drain_all(), vec![100]);
    }

    #[test]
    fn quarantine_spares_live_entries_and_returns_doomed_handles_once() {
        let mut m: KvCacheManager<u32> = unbounded();
        m.install(0, 10, 8); // stale-to-be, pinned
        m.install(1, 100, 8); // live, pinned
        m.install(2, 11, 8); // stale-to-be AND doomed while pinned
        assert!(m.release(2).is_empty(), "pinned release defers");
        let mut out = m.quarantine_stale(|&h| h < 100);
        out.sort_unstable();
        assert_eq!(out, vec![10, 11], "stale entries swept, live one spared");
        assert!(m.contains(1), "live entry stays resident");
        assert_eq!(m.stats().quarantined, 2);
        assert_eq!(m.resident_bytes(), 8);
        // orphaned unpins are no-ops: the doomed entry 11 is already gone
        // and must NOT surface a second time through the graveyard.
        m.unpin(0);
        m.unpin(2);
        m.unpin(1);
        assert!(m.pool().consistent());
        assert_eq!(m.release_all(), vec![100], "nothing returned twice");
    }

    #[test]
    fn eviction_skips_entries_pinned_by_other_streams() {
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::new(usize::MAX, 1)));
        let mut a = KvCacheManager::shared_view(&pool);
        let mut b = KvCacheManager::shared_view(&pool);
        let key = RepKey::of_parts(["bb"], [1]);
        a.bind(0, key);
        b.bind(0, key);
        assert_eq!(a.lookup(0), Lookup::MustInstall);
        a.install(0, 10, 1);
        a.unpin(0);
        assert!(b.lookup(0).is_hit()); // only B pins now

        // A installs a different rep under a one-entry budget: B's pinned
        // entry must survive (pool runs over budget instead).
        assert_eq!(a.lookup(1), Lookup::MustInstall);
        let evicted = a.install(1, 11, 1);
        assert!(evicted.is_empty(), "cross-stream pinned entry must not be evicted");
        assert_eq!(pool.len(), 2);

        b.unpin(0);
        a.unpin(1);
        let evicted = {
            assert_eq!(a.lookup(2), Lookup::MustInstall);
            a.install(2, 12, 1)
        };
        assert!(!evicted.is_empty(), "unpinned entries evict normally again");
        a.unpin(2);
        pool.drain_all();
    }

    #[test]
    fn view_drop_aborts_reservation_so_waiters_do_not_hang() {
        use std::sync::mpsc::channel;
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded()));
        let key = RepKey::of_parts(["bb"], [3]);
        let mut a = KvCacheManager::shared_view(&pool);
        a.bind(0, key);
        assert_eq!(a.lookup(0), Lookup::MustInstall); // reservation held

        let pool2 = Arc::clone(&pool);
        let (tx, rx) = channel();
        let waiter = std::thread::spawn(move || {
            let mut b = KvCacheManager::shared_view(&pool2);
            b.bind(0, key);
            tx.send(()).unwrap(); // about to block on A's reservation
            let out = b.lookup(0);
            b.abort_install(0);
            out
        });
        rx.recv().unwrap();
        // give the waiter time to actually park on the condvar
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(a); // unwound installer: reservation must be aborted
        let out = waiter.join().expect("waiter must not hang or panic");
        assert_eq!(out, Lookup::MustInstall,
                   "the waiter becomes the new installer after the abort");
    }

    #[test]
    fn contention_counters_move_under_lock_traffic() {
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded()));
        let mut v = KvCacheManager::shared_view(&pool);
        assert_eq!(v.lookup(0), Lookup::MustInstall);
        v.install(0, 1, 1);
        v.unpin(0);
        let ls = pool.lock_stats();
        assert!(ls.acquisitions >= 3, "every op takes the lock: {ls:?}");
        assert!(ls.contended <= ls.acquisitions);
        pool.drain_all();
    }

    #[test]
    fn rep_key_is_content_sensitive() {
        let k = |s: &'static str, ids: &[u64]| RepKey::of_parts([s], ids.iter().copied());
        assert_eq!(k("bb", &[1, 2]), k("bb", &[1, 2]));
        assert_ne!(k("bb", &[1, 2]), k("bb", &[2, 1]), "order matters");
        assert_ne!(k("bb", &[1, 2]), k("bb2", &[1, 2]));
        assert_ne!(RepKey::of_parts(["ab", "c"], []), RepKey::of_parts(["a", "bc"], []));
    }

    // -- host-tier unit tests ------------------------------------------------

    /// Tiered policy: one device slot, roomy host tier.
    fn tiered(host_bytes: usize) -> CachePolicy {
        CachePolicy::new(usize::MAX, 1).with_host_bytes(host_bytes)
    }

    #[test]
    fn demote_then_promote_roundtrip_bookkeeping() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(tiered(1 << 20));
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        let out = m.install_tiered(0, 10, 64);
        assert!(out.release.is_empty() && out.demote.is_empty());
        m.unpin(0);

        // installing cluster 1 overflows the single device slot: cluster
        // 0's handle leaves as a Demotion work item, not a release.
        assert_eq!(m.lookup(1), Lookup::MustInstall);
        let out = m.install_tiered(1, 11, 64);
        assert!(out.release.is_empty(), "host tier on: eviction does not destroy");
        assert_eq!(out.demote.len(), 1);
        let d = out.demote.into_iter().next().unwrap();
        assert_eq!(d.handle, 10);
        assert_eq!(d.slot.bytes(), 64);
        m.unpin(1);

        // the caller "copies" 10 off-device as host handle 1010.
        assert!(m.admit_host(d.slot, 1010).release.is_empty());
        assert!(m.contains_host(0));
        assert!(!m.contains(0));
        assert_eq!(m.pool().host_resident_bytes(), 64);
        assert_eq!(m.pool().host_len(), 1);

        // a lookup of cluster 0 finds the host copy: checkout + promote.
        assert_eq!(m.lookup(0), Lookup::MustPromote);
        let (host, bytes) = m.take_promotion(0).expect("checkout must be stashed");
        assert_eq!((host, bytes), (1010, 64));
        assert!(!m.contains_host(0), "checkout removes the host copy");
        // promoting installs the fresh device handle; cluster 1 demotes in
        // turn (single device slot).
        let out = m.install_promoted(0, 20, 64);
        assert!(out.release.is_empty());
        assert_eq!(out.demote.len(), 1);
        assert_eq!(out.demote[0].handle, 11);
        m.unpin(0);

        let s = m.stats();
        assert_eq!(s.prefills, 2, "promotion is not a prefill");
        assert_eq!(s.promotions, 1);
        assert_eq!(s.host_hits, 1);
        assert_eq!(s.demotions, 1);
        assert_eq!(s.misses, 3, "a host hit still counts as a device miss");
        assert_eq!(s.evictions, 2, "demotions are still budget evictions");
        assert!(m.pool().consistent());
        // drop the un-admitted second demotion + drain: every handle
        // surfaces exactly once across tiers.
        let mut all = m.release_all();
        all.push(out.demote.into_iter().next().unwrap().handle);
        all.sort_unstable();
        assert_eq!(all, vec![11, 20]);
    }

    #[test]
    fn install_supersedes_host_copy_of_same_key() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(tiered(1 << 20));
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        m.install_tiered(0, 10, 8);
        m.unpin(0);
        assert_eq!(m.lookup(1), Lookup::MustInstall);
        let out = m.install_tiered(1, 11, 8);
        let d = out.demote.into_iter().next().unwrap();
        assert!(m.admit_host(d.slot, 1010).release.is_empty());
        m.unpin(1);

        // a caller that answers MustPromote with a plain prefill: the
        // stale checkout is buried, the host copy never resurfaces as a
        // second live copy, and the fresh install wins.
        assert_eq!(m.lookup(0), Lookup::MustPromote);
        let out = m.install_tiered(0, 20, 8);
        assert!(!m.contains_host(0), "checkout already removed the host copy");
        assert_eq!(out.release, vec![1010],
                   "the buried checkout surfaces exactly once, at the install's drain");
        assert_eq!(out.demote.len(), 1, "cluster 1 demotes under the budget");
        assert_eq!(out.demote[0].handle, 11);
        m.unpin(0);
        assert!(m.pool().consistent());
        assert_eq!(m.release_all(), vec![20]);
    }

    #[test]
    fn host_budget_exhaustion_kills_coldest_copy() {
        // host tier fits exactly one 64-byte copy: admitting a second
        // demotion kills the first (LRU demotion-to-death).
        let mut m: KvCacheManager<u32> = KvCacheManager::new(tiered(64));
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        m.install_tiered(0, 10, 64);
        m.unpin(0);
        assert_eq!(m.lookup(1), Lookup::MustInstall);
        let d0 = m.install_tiered(1, 11, 64).demote.into_iter().next().unwrap();
        m.unpin(1);
        assert!(m.admit_host(d0.slot, 1010).release.is_empty());
        assert_eq!(m.lookup(2), Lookup::MustInstall);
        let d1 = m.install_tiered(2, 12, 64).demote.into_iter().next().unwrap();
        m.unpin(2);
        let dead = m.admit_host(d1.slot, 1011);
        assert_eq!(dead.release, vec![1010], "oldest host copy dies under the budget");
        assert_eq!(m.pool().host_resident_bytes(), 64);
        // the killed copy's key is now a true miss again.
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        m.abort_install(0);
        assert!(m.contains_host(1), "survivor still promotable");
        assert!(m.pool().consistent());
        m.release_all();
    }

    #[test]
    fn host_tier_disabled_keeps_legacy_eviction() {
        let mut m: KvCacheManager<u32> =
            KvCacheManager::new(CachePolicy::new(usize::MAX, 1));
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        m.install_tiered(0, 10, 8);
        m.unpin(0);
        assert_eq!(m.lookup(1), Lookup::MustInstall);
        let out = m.install_tiered(1, 11, 8);
        assert_eq!(out.release, vec![10], "host tier off: eviction destroys");
        assert!(out.demote.is_empty());
        assert_eq!(m.stats().demotions, 0);
        m.unpin(1);
        m.release_all();
    }

    #[test]
    fn redundant_host_admission_is_released_not_counted() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(tiered(1 << 20));
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        m.install_tiered(0, 10, 8);
        m.unpin(0);
        assert_eq!(m.lookup(1), Lookup::MustInstall);
        let d = m.install_tiered(1, 11, 8).demote.into_iter().next().unwrap();
        m.unpin(1);
        // before the demotion copy lands, the key is re-prefilled: the
        // slow copy is redundant and must come straight back for release.
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        let out = m.install_tiered(0, 20, 8);
        assert_eq!(out.demote.len(), 1, "cluster 1 demotes in turn");
        m.unpin(0);
        let back = m.admit_host(d.slot, 1010);
        assert_eq!(back.release, vec![1010], "redundant copy released, not admitted");
        assert_eq!(m.stats().demotions, 0);
        assert_eq!(m.pool().host_len(), 0);
        assert!(m.pool().consistent());
        m.release_all();
    }

    #[test]
    fn quarantine_spares_host_tier_copies() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(tiered(1 << 20));
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        m.install_tiered(0, 10, 8);
        m.unpin(0);
        assert_eq!(m.lookup(1), Lookup::MustInstall);
        let d = m.install_tiered(1, 11, 8).demote.into_iter().next().unwrap();
        assert!(m.admit_host(d.slot, 1010).release.is_empty());

        // the lane dies: every device handle is stale, the host copy is not.
        let dead = m.quarantine_stale(|_| true);
        assert_eq!(dead, vec![11], "only the device entry is swept");
        assert!(m.contains_host(0), "host copy survives the lane death");
        assert_eq!(m.lookup(0), Lookup::MustPromote,
                   "post-quarantine lookup re-promotes instead of repaying");
        let (host, _) = m.take_promotion(0).unwrap();
        assert_eq!(host, 1010);
        let out = m.install_promoted(0, 20, 8);
        assert!(out.release.is_empty() && out.demote.is_empty());
        m.unpin(1); // orphaned by the sweep: no-op
        m.unpin(0);
        assert_eq!(m.stats().promotions, 1);
        assert!(m.pool().consistent());
        assert_eq!(m.release_all(), vec![20]);
    }

    #[test]
    fn view_tier_counters_sum_to_pool() {
        // two shared views drive demote/promote traffic; per-view tier
        // counters must sum to the pool's, and `released` must agree at
        // every drain point.
        let pool: Arc<SharedKvCache<u32>> = Arc::new(SharedKvCache::new(
            CachePolicy::new(usize::MAX, 1).with_host_bytes(1 << 20),
        ));
        let mut a = KvCacheManager::shared_view(&pool);
        let mut b = KvCacheManager::shared_view(&pool);
        let ka = RepKey::of_parts(["bb"], [1]);
        let kb = RepKey::of_parts(["bb"], [2]);
        a.bind(0, ka);
        b.bind(0, kb);
        b.bind(1, ka);

        assert_eq!(a.lookup(0), Lookup::MustInstall);
        a.install_tiered(0, 10, 8);
        a.unpin(0);
        assert_eq!(b.lookup(0), Lookup::MustInstall);
        let d = b.install_tiered(0, 11, 8).demote.into_iter().next().unwrap();
        b.unpin(0);
        assert!(b.admit_host(d.slot, 1010).release.is_empty());
        assert_eq!(b.lookup(1), Lookup::MustPromote, "B promotes A's demoted rep");
        let (host, bytes) = b.take_promotion(1).unwrap();
        assert_eq!(host, 1010);
        let _ = b.install_promoted(1, 20, bytes);
        b.unpin(1);

        let (pa, pb, pp) = (a.stats(), b.stats(), pool.stats());
        assert_eq!(pa.prefills + pb.prefills, pp.prefills);
        assert_eq!(pa.misses + pb.misses, pp.misses);
        assert_eq!(pa.demotions + pb.demotions, pp.demotions);
        assert_eq!(pa.promotions + pb.promotions, pp.promotions);
        assert_eq!(pa.host_hits + pb.host_hits, pp.host_hits);
        assert_eq!(pa.evictions + pb.evictions, pp.evictions);
        assert_eq!(pa.released + pb.released, pp.released);
        assert_eq!(pp.demotions, 1);
        assert_eq!(pp.promotions, 1);
        assert_eq!(pp.host_hits, 1);
        // final drain: remaining handles surface exactly once, and the
        // pool's released counter ends equal to every handle ever returned.
        let mut drained = pool.drain_all();
        drained.extend(
            b.install_tiered(0, 30, 8)
                .into_release_all(),
        );
        b.unpin(0);
        drained.extend(pool.drain_all());
        drained.sort_unstable();
        assert!(pool.consistent());
        assert!(drained.contains(&20) || drained.contains(&11));
    }

    // -- disk-tier unit tests ------------------------------------------------

    /// Three-tier policy: one device slot, `host_bytes` host, `disk_bytes`
    /// disk.
    fn three_tier(host_bytes: usize, disk_bytes: usize) -> CachePolicy {
        CachePolicy::new(usize::MAX, 1)
            .with_host_bytes(host_bytes)
            .with_disk_bytes(disk_bytes)
    }

    /// Drive cluster 0's KV device → host → disk: install clusters 0..=2
    /// through a single device slot and a single-copy host budget, so
    /// cluster 0's host copy (handle 1010) spills to disk as payload
    /// `b"kv0"`.
    fn spill_to_disk(m: &mut KvCacheManager<u32>) {
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        let out = m.install_tiered(0, 10, 64);
        assert!(out.release.is_empty() && out.demote.is_empty());
        m.unpin(0);
        assert_eq!(m.lookup(1), Lookup::MustInstall);
        let d0 = m.install_tiered(1, 11, 64).demote.into_iter().next().unwrap();
        m.unpin(1);
        assert!(m.admit_host(d0.slot, 1010).release.is_empty());
        assert_eq!(m.lookup(2), Lookup::MustInstall);
        let d1 = m.install_tiered(2, 12, 64).demote.into_iter().next().unwrap();
        m.unpin(2);
        let HostAdmit { release, archive } = m.admit_host(d1.slot, 1011);
        assert!(release.is_empty(), "disk tier on: a host death spills, not dies");
        assert_eq!(archive.len(), 1);
        let a = archive.into_iter().next().unwrap();
        assert_eq!(a.handle, 1010);
        assert_eq!(a.slot.bytes(), 64);
        assert!(m.admit_disk(a.slot, b"kv0"), "spill must be admitted");
    }

    #[test]
    fn host_death_spills_to_disk_and_recalls_roundtrip() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(three_tier(64, 1 << 20));
        spill_to_disk(&mut m);
        assert_eq!(m.pool().disk_len(), 1);
        assert_eq!(m.pool().disk_resident_bytes(), 64);

        // a revisit finds the archived record: checkout consumes it and
        // hands the payload back intact for the recall walk.
        assert_eq!(m.lookup(0), Lookup::MustRecall);
        let (payload, bytes) = m.take_recall(0).expect("checkout must be stashed");
        assert_eq!((payload.as_slice(), bytes), (&b"kv0"[..], 64));
        assert_eq!(m.pool().disk_len(), 0, "checkout consumes the record");
        let out = m.install_recalled(0, 20, 64);
        assert!(out.release.is_empty());
        assert_eq!(out.demote.len(), 1, "cluster 2 demotes under the device budget");
        m.unpin(0);

        let s = m.stats();
        assert_eq!(s.prefills, 3, "a recall is not a prefill");
        assert_eq!(s.recalls, 1);
        assert_eq!(s.disk_hits, 1);
        assert_eq!(s.archived, 1);
        assert_eq!(s.demotions, 2);
        assert!(m.pool().consistent());
        let mut all = m.release_all();
        all.extend(out.demote.into_iter().map(|d| d.handle));
        all.sort_unstable();
        assert_eq!(all, vec![12, 20, 1011]);
    }

    #[test]
    fn torn_archive_record_reads_as_plain_miss() {
        // crash-partial coverage: a corrupted payload fails the checksum,
        // the record is consumed, and the lookup degrades to MustInstall —
        // never a panic or a poisoned pool.
        let mut m: KvCacheManager<u32> = KvCacheManager::new(three_tier(64, 1 << 20));
        spill_to_disk(&mut m);
        let path = m.pool().disk_archive_path().expect("archive file exists");
        let mut data = std::fs::read(&path).unwrap();
        let n = data.len();
        data[n - 1] ^= 0xFF; // flip the last payload byte
        std::fs::write(&path, &data).unwrap();

        assert_eq!(m.lookup(0), Lookup::MustInstall, "torn record is a miss");
        assert_eq!(m.pool().disk_len(), 0, "torn record is consumed either way");
        assert_eq!(m.stats().disk_hits, 0, "a torn checkout is not a disk hit");
        m.abort_install(0);
        assert!(m.pool().consistent());
        m.release_all();
    }

    #[test]
    fn truncated_archive_record_reads_as_plain_miss() {
        // the other crash-partial shape: the file ends mid-record.
        let mut m: KvCacheManager<u32> = KvCacheManager::new(three_tier(64, 1 << 20));
        spill_to_disk(&mut m);
        let path = m.pool().disk_archive_path().expect("archive file exists");
        let n = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(n - 2).unwrap();

        assert_eq!(m.lookup(0), Lookup::MustInstall, "truncated record is a miss");
        assert_eq!(m.pool().disk_len(), 0);
        m.abort_install(0);
        assert!(m.pool().consistent());
        m.release_all();
    }

    #[test]
    fn disk_budget_kills_coldest_record() {
        let m: KvCacheManager<u32> = KvCacheManager::new(three_tier(1 << 20, 64));
        let pool = m.pool();
        assert!(pool.admit_disk(DiskSlot { key: 7, bytes: 64 }, b"cold"));
        assert!(pool.admit_disk(DiskSlot { key: 8, bytes: 64 }, b"warm"));
        assert_eq!(pool.disk_len(), 1, "budget fits exactly one record");
        let mut arc = pool.lock_disk().unwrap();
        assert!(!arc.index.contains_key(&7), "coldest record died");
        assert_eq!(arc.checkout(8), Some((b"warm".to_vec(), 64)));
    }

    #[test]
    fn redundant_and_oversized_archivals_are_dropped() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(three_tier(1 << 20, 64));
        // oversized: the payload's logical bytes outgrow the whole budget.
        assert!(!m.admit_disk(DiskSlot { key: 7, bytes: 128 }, b"too-big"));
        // duplicate key: the first admit wins.
        assert!(m.admit_disk(DiskSlot { key: 8, bytes: 32 }, b"first"));
        assert!(!m.admit_disk(DiskSlot { key: 8, bytes: 32 }, b"second"));
        // key live in a higher tier: dropped.
        assert_eq!(m.lookup(0), Lookup::MustInstall);
        let key = m.key_of(0);
        m.install_tiered(0, 10, 8);
        assert!(!m.admit_disk(DiskSlot { key, bytes: 8 }, b"resident"));
        assert_eq!(m.stats().archived, 1, "only the first admit counted");
        m.unpin(0);
        assert!(m.pool().consistent());
        m.release_all();
    }

    #[test]
    fn install_and_release_kill_archived_records() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(three_tier(64, 1 << 20));
        spill_to_disk(&mut m);
        assert_eq!(m.pool().disk_len(), 1);
        // a blind re-install of the archived key (the in-batch pipeline
        // pattern: no lookup first) supersedes the disk record.
        let out = m.install_tiered(0, 30, 64);
        assert_eq!(m.pool().disk_len(), 0, "resident install kills the disk copy");
        drop(out.into_release_all());
        m.unpin(0);
        // ... and an explicit release kills one too.
        let d = m.install_tiered(1, 31, 64).demote.into_iter().next().unwrap();
        m.unpin(1);
        let HostAdmit { archive, .. } = m.admit_host(d.slot, 2030);
        let a = archive.into_iter().next().unwrap();
        assert!(m.admit_disk(a.slot, b"kv0-again"));
        assert_eq!(m.pool().disk_len(), 1);
        m.release(0);
        assert_eq!(m.pool().disk_len(), 0, "release kills the disk copy");
        assert!(m.pool().consistent());
        m.release_all();
    }

    #[test]
    fn archive_compacts_when_dead_bytes_exceed_live() {
        let mut arc = ArchiveInner::new();
        arc.append(1, 64, 1, &[0xAB; 100]).unwrap();
        arc.append(2, 64, 2, b"two").unwrap();
        assert!(arc.kill(1));
        assert!(arc.dead_file > arc.live_file, "dead bytes dominate");
        arc.maybe_compact();
        assert_eq!(arc.compactions, 1);
        assert_eq!(arc.checkout(1), None, "dead record stays dead");
        assert_eq!(
            arc.checkout(2),
            Some((b"two".to_vec(), 64)),
            "survivor reads back intact after the rewrite"
        );
    }

    #[test]
    fn released_counts_each_handle_exactly_once_property() {
        // The `released` contract across all three tiers: it counts
        // exactly the handles handed back for disposal, once, at the call
        // that returns them. Handles leaving for use (demotions,
        // archivals, promotion checkouts) never count until they come
        // back. Walk a random tiered schedule, tally every disposal the
        // view hands us, and compare with the counter.
        prop_check(120, |rng| {
            let policy = CachePolicy::new(usize::MAX, rng.range(1, 3))
                .with_host_bytes(rng.range(32, 128))
                .with_disk_bytes(rng.range(64, 256));
            let mut m: KvCacheManager<u64> = KvCacheManager::new(policy);
            let mut next = 1u64;
            let mut disposed = 0u64;
            let mut seen = std::collections::HashSet::new();
            fn dispose(hs: Vec<u64>, disposed: &mut u64, seen: &mut std::collections::HashSet<u64>) {
                for h in hs {
                    assert!(seen.insert(h), "handle {h} disposed twice");
                    *disposed += 1;
                }
            }
            // park a demotion/archival chain: copy off-device (host handle
            // = device | HOST tag), then serialize host-budget spills.
            let mut settle = |m: &mut KvCacheManager<u64>,
                              out: TieredOut<u64>,
                              disposed: &mut u64,
                              seen: &mut std::collections::HashSet<u64>| {
                dispose(out.release, disposed, seen);
                for d in out.demote {
                    let host = d.handle | (1 << 48);
                    let adm = m.admit_host(d.slot, host);
                    dispose(adm.release, disposed, seen);
                    for a in adm.archive {
                        // archive_kv consumes the host handle for use —
                        // it is never disposed, only its bytes survive.
                        let _ = m.admit_disk(a.slot, &a.handle.to_le_bytes());
                    }
                }
            };
            for _ in 0..rng.range(5, 40) {
                let cid = rng.below(5);
                match m.lookup(cid) {
                    Lookup::Hit => {
                        m.unpin(cid);
                    }
                    Lookup::MustInstall => {
                        let h = next;
                        next += 1;
                        let out = m.install_tiered(cid, h, rng.range(16, 64));
                        settle(&mut m, out, &mut disposed, &mut seen);
                        m.unpin(cid);
                    }
                    Lookup::MustPromote => {
                        // the checkout is consumed by the copy-up: the
                        // host handle leaves for use, never disposed.
                        let (_host, bytes) = m.take_promotion(cid).unwrap();
                        let h = next;
                        next += 1;
                        let out = m.install_promoted(cid, h, bytes);
                        settle(&mut m, out, &mut disposed, &mut seen);
                        m.unpin(cid);
                    }
                    Lookup::MustRecall => {
                        let (_payload, bytes) = m.take_recall(cid).unwrap();
                        let h = next;
                        next += 1;
                        let out = m.install_recalled(cid, h, bytes);
                        settle(&mut m, out, &mut disposed, &mut seen);
                        m.unpin(cid);
                    }
                }
                if rng.below(4) == 0 {
                    dispose(m.release(rng.below(5)), &mut disposed, &mut seen);
                }
            }
            dispose(m.release_all(), &mut disposed, &mut seen);
            assert_eq!(
                m.stats().released,
                disposed,
                "released must equal handles disposed, each counted once"
            );
            assert!(m.pool().consistent());
        });
    }

    #[test]
    fn sharded_pool_single_shard_degenerates() {
        // shards = 1 must behave exactly like the pre-sharding pool.
        let mut m: KvCacheManager<u32> =
            KvCacheManager::new(CachePolicy::new(usize::MAX, 2).with_shards(1));
        serve_install(&mut m, 0, 10, 1);
        m.unpin(0);
        serve_install(&mut m, 1, 11, 1);
        m.unpin(1);
        assert!(m.lookup(0).is_hit());
        m.unpin(0);
        let evicted = serve_install(&mut m, 2, 12, 1);
        assert_eq!(evicted, vec![11]);
        assert_eq!(m.pool().shard_lock_stats().len(), 1);
        m.unpin(2);
        m.release_all();
    }

    #[test]
    fn shard_lock_stats_split_covers_all_shards() {
        let pool: Arc<SharedKvCache<u32>> =
            Arc::new(SharedKvCache::new(CachePolicy::unbounded().with_shards(4)));
        let mut v = KvCacheManager::shared_view(&pool);
        for cid in 0..16 {
            assert_eq!(v.lookup(cid), Lookup::MustInstall);
            v.install(cid, cid as u32, 1);
            v.unpin(cid);
        }
        let per_shard = pool.shard_lock_stats();
        assert_eq!(per_shard.len(), 4);
        let summed: u64 = per_shard.iter().map(|s| s.acquisitions).sum();
        assert_eq!(summed, pool.lock_stats().acquisitions);
        assert!(summed >= 32, "every op takes some shard lock: {summed}");
        pool.drain_all();
    }
}
