//! Subgraph-level KV cache manager (the paper §3.4), grown from the seed's
//! single-resident slot into a real admission/eviction policy.
//!
//! Several cluster-representative KV caches can now be resident at once,
//! bounded by a [`CachePolicy`] byte/entry budget with LRU eviction — the
//! knowledge-caching direction RAGCache takes for RAG prefixes. This is what
//! the online (streaming) serving path needs: a query that lands on a
//! previously seen cluster reuses the still-warm representative cache
//! instead of re-prefilling it.
//!
//! Entry lifecycle:
//!
//! 1. [`KvCacheManager::install`] admits a representative cache **pinned**,
//!    so a concurrent admission can never evict the in-flight cluster
//!    mid-extend. Evicted handles are returned to the caller, who must hand
//!    them back to the engine (batched via
//!    [`crate::runtime::Engine::release_many`]).
//! 2. [`KvCacheManager::lookup`] hits refresh the entry's LRU position and
//!    bank the avoided prefill bytes in [`CacheStats::bytes_saved`].
//! 3. [`KvCacheManager::unpin`] when the cluster/request completes makes the
//!    entry evictable; [`KvCacheManager::release_all`] drains the cache at
//!    end of batch.
//!
//! Eviction only ever removes unpinned entries, least-recently-used first.
//! If pinned entries alone exceed the budget the cache runs over budget
//! rather than corrupting in-flight state (the property tests below pin this
//! down). Generic over the handle type so the policy is testable without a
//! PJRT engine; the real handle is [`crate::runtime::KvHandle`].

/// Admission/eviction budget for the multi-resident cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CachePolicy {
    /// Total bytes of resident KV caches (k + v) the manager may hold.
    pub max_bytes: usize,
    /// Maximum number of concurrently resident representative caches.
    pub max_entries: usize,
}

impl Default for CachePolicy {
    /// Multi-resident by default: up to 4 warm representatives, no byte cap
    /// (the simulated backbones are small; real deployments set `max_bytes`).
    fn default() -> Self {
        CachePolicy { max_bytes: usize::MAX, max_entries: 4 }
    }
}

impl CachePolicy {
    pub fn new(max_bytes: usize, max_entries: usize) -> Self {
        CachePolicy { max_bytes, max_entries }
    }

    /// No budget at all — every representative stays warm.
    pub fn unbounded() -> Self {
        CachePolicy { max_bytes: usize::MAX, max_entries: usize::MAX }
    }

    /// The seed's behaviour: at most one resident representative.
    pub fn single_resident() -> Self {
        CachePolicy { max_bytes: usize::MAX, max_entries: 1 }
    }
}

/// Accounting snapshot (reported in EXPERIMENTS.md and the table harnesses).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Installs = representative prefills actually paid.
    pub prefills: u64,
    /// Lookups that found a warm resident cache.
    pub hits: u64,
    /// Lookups that found nothing (new cluster or evicted).
    pub misses: u64,
    /// Entries removed by the budget policy (subset of `released`).
    pub evictions: u64,
    /// Handles returned to the caller, by eviction or explicit release.
    pub released: u64,
    /// KV bytes of prefill work avoided: sum of entry bytes over hits.
    pub bytes_saved: u64,
    pub resident_bytes: usize,
    pub peak_bytes: usize,
}

impl CacheStats {
    /// Warm-hit rate over all lookups (0.0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 { 0.0 } else { self.hits as f64 / total as f64 }
    }
}

/// One resident cluster cache.
struct Entry<H> {
    cluster_id: usize,
    handle: H,
    bytes: usize,
    pins: u32,
    last_used: u64,
}

/// The byte-budgeted, multi-resident subgraph-level KV cache. `H` is an
/// opaque device-cache handle; every handle passed to [`install`] is
/// eventually returned exactly once (via the eviction vectors, `release`, or
/// `release_all`) so the caller can return it to the engine.
///
/// [`install`]: KvCacheManager::install
pub struct KvCacheManager<H> {
    policy: CachePolicy,
    entries: Vec<Entry<H>>,
    tick: u64,
    stats: CacheStats,
}

impl<H> Default for KvCacheManager<H> {
    fn default() -> Self {
        Self::new(CachePolicy::default())
    }
}

impl<H> KvCacheManager<H> {
    pub fn new(policy: CachePolicy) -> Self {
        assert!(policy.max_entries >= 1, "policy must admit at least one entry");
        KvCacheManager { policy, entries: Vec::new(), tick: 0, stats: CacheStats::default() }
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn idx(&self, cluster_id: usize) -> Option<usize> {
        self.entries.iter().position(|e| e.cluster_id == cluster_id)
    }

    /// Install the KV cache of `cluster_id`'s representative subgraph. The
    /// entry is admitted **pinned** (call [`unpin`] once the cluster's
    /// in-flight work completes). Returns every handle the caller must
    /// release on the engine: entries evicted to make room, an unpinned
    /// same-cluster entry this install replaces, or — if the cluster is
    /// already resident *and pinned* — the rejected new `handle` itself
    /// (the warm in-flight entry wins).
    ///
    /// [`unpin`]: KvCacheManager::unpin
    pub fn install(&mut self, cluster_id: usize, handle: H, bytes: usize) -> Vec<H> {
        // peak is taken up front: the incoming cache coexists on the device
        // with every current resident — including any entries about to be
        // evicted or replaced — until the caller releases the returned
        // handles, so this transient sum is the honest high-water mark.
        self.stats.peak_bytes =
            self.stats.peak_bytes.max(self.stats.resident_bytes + bytes);
        let mut out = Vec::new();
        // re-installing a cluster replaces its entry (e.g. a representative
        // rebuilt after eviction raced with a concurrent admission) — unless
        // the resident entry is pinned: an in-flight extend may hold its
        // handle, so the only safe answer is to keep it and hand the NEW
        // handle straight back for release.
        if let Some(i) = self.idx(cluster_id) {
            if self.entries[i].pins > 0 {
                self.stats.released += 1;
                return vec![handle];
            }
            // replacement is not budget pressure: count the returned handle
            // in `released` only, never in `evictions`.
            let e = self.entries.swap_remove(i);
            self.stats.released += 1;
            self.stats.resident_bytes -= e.bytes;
            out.push(e.handle);
        }
        let last_used = self.bump();
        self.stats.prefills += 1;
        self.stats.resident_bytes += bytes;
        self.entries.push(Entry { cluster_id, handle, bytes, pins: 1, last_used });
        while self.over_budget() {
            match self.lru_unpinned() {
                Some(i) => out.push(self.evict_at(i)),
                None => break, // only pinned entries left: run over budget
            }
        }
        out
    }

    fn over_budget(&self) -> bool {
        self.stats.resident_bytes > self.policy.max_bytes
            || self.entries.len() > self.policy.max_entries
    }

    fn lru_unpinned(&self) -> Option<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.pins == 0)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
    }

    fn evict_at(&mut self, i: usize) -> H {
        let e = self.entries.swap_remove(i);
        self.stats.evictions += 1;
        self.stats.released += 1;
        self.stats.resident_bytes -= e.bytes;
        e.handle
    }

    /// Look up the resident cache for a cluster. A hit refreshes the entry's
    /// LRU position and counts the avoided prefill bytes as saved.
    pub fn lookup(&mut self, cluster_id: usize) -> Option<&H> {
        match self.idx(cluster_id) {
            Some(i) => {
                let t = self.bump();
                let bytes = {
                    let e = &mut self.entries[i];
                    e.last_used = t;
                    e.bytes
                };
                self.stats.hits += 1;
                self.stats.bytes_saved += bytes as u64;
                Some(&self.entries[i].handle)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Non-mutating residency probe (no stats, no LRU refresh).
    pub fn contains(&self, cluster_id: usize) -> bool {
        self.idx(cluster_id).is_some()
    }

    /// Borrow a resident handle without touching stats or LRU order — for
    /// serving code that already recorded the hit with [`lookup`].
    ///
    /// [`lookup`]: KvCacheManager::lookup
    pub fn peek(&self, cluster_id: usize) -> Option<&H> {
        self.idx(cluster_id).map(|i| &self.entries[i].handle)
    }

    /// Protect a resident entry from eviction (pins nest). Returns false if
    /// the cluster is not resident.
    pub fn pin(&mut self, cluster_id: usize) -> bool {
        match self.idx(cluster_id) {
            Some(i) => {
                self.entries[i].pins += 1;
                true
            }
            None => false,
        }
    }

    /// Drop one pin from a resident entry. Returns false if the cluster is
    /// not resident or was not pinned.
    pub fn unpin(&mut self, cluster_id: usize) -> bool {
        match self.idx(cluster_id) {
            Some(i) if self.entries[i].pins > 0 => {
                self.entries[i].pins -= 1;
                true
            }
            _ => false,
        }
    }

    pub fn is_pinned(&self, cluster_id: usize) -> bool {
        self.pin_count(cluster_id) > 0
    }

    /// Current pin count of a resident entry (0 when absent). Pins nest,
    /// and under pipelined serving they are the lifetime anchor for
    /// in-flight engine tickets: a cluster is pinned from before its
    /// prefill/extend ticket is submitted until after `wait` returns, so
    /// host-side overlap work running in the ticket's shadow can never
    /// admit an entry that evicts the one the device is still reading.
    pub fn pin_count(&self, cluster_id: usize) -> u32 {
        self.idx(cluster_id).map(|i| self.entries[i].pins).unwrap_or(0)
    }

    /// Explicitly release one cluster's cache (pins are the caller's own
    /// bookkeeping at this point and are discarded). Returns its handle.
    pub fn release(&mut self, cluster_id: usize) -> Option<H> {
        self.idx(cluster_id).map(|i| {
            let e = self.entries.swap_remove(i);
            self.stats.released += 1;
            self.stats.resident_bytes -= e.bytes;
            e.handle
        })
    }

    /// Drain every resident entry (end of batch), pinned or not. Returns all
    /// handles for the caller to release on the engine.
    pub fn release_all(&mut self) -> Vec<H> {
        let mut drained = Vec::with_capacity(self.entries.len());
        for e in self.entries.drain(..) {
            drained.push(e.handle);
        }
        self.stats.released += drained.len() as u64;
        self.stats.resident_bytes = 0;
        drained
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn resident_bytes(&self) -> usize {
        self.stats.resident_bytes
    }

    /// Resident cluster ids, sorted (deterministic for tests/diagnostics).
    pub fn resident_clusters(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.entries.iter().map(|e| e.cluster_id).collect();
        ids.sort_unstable();
        ids
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

// No Drop assertion: the serve paths legitimately drop a manager with
// entries still resident when an engine call errors mid-batch (`?` unwinds
// past the end-of-batch `release_all` drain). The handles inside are
// engine-owned ids — the engine reclaims their buffers at shutdown — so the
// cost of an early drop is a bounded leak for the engine's lifetime, not
// corruption. Success paths drain via `release_all` (checked by the e2e
// `live_kv` leak tests) so buffers free promptly.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn unbounded<H>() -> KvCacheManager<H> {
        KvCacheManager::new(CachePolicy::unbounded())
    }

    #[test]
    fn install_lookup_release_cycle() {
        let mut m: KvCacheManager<u32> = unbounded();
        assert!(m.lookup(0).is_none());
        assert!(m.install(0, 111, 1024).is_empty());
        assert_eq!(m.lookup(0), Some(&111));
        assert_eq!(m.lookup(0), Some(&111));
        assert!(m.lookup(1).is_none()); // other cluster: miss, no eviction
        assert_eq!(m.resident_clusters(), vec![0]);
        m.unpin(0);
        assert_eq!(m.release(0), Some(111));
        assert!(m.lookup(0).is_none());
        let s = m.stats();
        assert_eq!((s.prefills, s.hits, s.misses, s.released), (1, 2, 3, 1));
        assert_eq!(s.bytes_saved, 2 * 1024);
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.peak_bytes, 1024);
        assert!((s.hit_rate() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn multiple_residents_under_budget() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(1000, 8));
        for cid in 0..3 {
            assert!(m.install(cid, cid as u32, 100).is_empty());
            m.unpin(cid);
        }
        assert_eq!(m.len(), 3);
        for cid in 0..3 {
            assert_eq!(m.lookup(cid), Some(&(cid as u32)));
        }
        assert_eq!(m.resident_bytes(), 300);
        let drained = m.release_all();
        assert_eq!(drained.len(), 3);
    }

    #[test]
    fn lru_eviction_under_entry_budget() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(usize::MAX, 2));
        m.install(0, 10, 1);
        m.unpin(0);
        m.install(1, 11, 1);
        m.unpin(1);
        m.lookup(0); // 0 is now more recently used than 1
        let evicted = m.install(2, 12, 1);
        assert_eq!(evicted, vec![11], "LRU entry (cluster 1) must go first");
        assert_eq!(m.resident_clusters(), vec![0, 2]);
        m.unpin(2);
        m.release_all();
    }

    #[test]
    fn byte_budget_evicts_down() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(250, 8));
        m.install(0, 10, 100);
        m.unpin(0);
        m.install(1, 11, 100);
        m.unpin(1);
        // 100 + 100 + 100 > 250: the two oldest unpinned entries fall out
        // until the budget holds again.
        let evicted = m.install(2, 12, 100);
        assert_eq!(evicted, vec![10]);
        assert_eq!(m.resident_bytes(), 200);
        m.unpin(2);
        m.release_all();
    }

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(usize::MAX, 1));
        m.install(0, 10, 1); // still pinned (in-flight)
        let evicted = m.install(1, 11, 1);
        assert!(evicted.is_empty(), "pinned cluster 0 must not be evicted");
        assert_eq!(m.len(), 2, "over budget rather than evict pinned");
        m.unpin(0);
        // next admission can now reclaim cluster 0
        let evicted = m.install(2, 12, 1);
        assert_eq!(evicted, vec![10]);
        m.unpin(1);
        m.unpin(2);
        assert_eq!(m.release_all().len(), 2);
    }

    #[test]
    fn single_resident_policy_degenerates_to_seed() {
        // max_entries = 1 with unpin-before-next-install reproduces the
        // seed's one-slot behaviour: each install evicts the previous.
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::single_resident());
        m.install(0, 1, 10);
        m.unpin(0);
        let evicted = m.install(1, 2, 20);
        assert_eq!(evicted, vec![1]);
        assert_eq!(m.resident_clusters(), vec![1]);
        assert_eq!(m.stats().peak_bytes, 30); // both resident inside install
        m.unpin(1);
        m.release_all();
    }

    #[test]
    fn reinstall_replaces_and_returns_old_handle() {
        let mut m: KvCacheManager<u32> = unbounded();
        m.install(0, 1, 10);
        m.unpin(0);
        let evicted = m.install(0, 2, 20);
        assert_eq!(evicted, vec![1]);
        assert_eq!(m.lookup(0), Some(&2));
        assert_eq!(m.resident_bytes(), 20);
        m.unpin(0);
        m.release_all();
    }

    #[test]
    fn reinstall_over_pinned_cluster_rejects_new_handle() {
        // An in-flight (pinned) entry may be mid-extend: a racing duplicate
        // install must not evict it. The new handle comes straight back.
        let mut m: KvCacheManager<u32> = unbounded();
        m.install(0, 1, 10); // still pinned
        let returned = m.install(0, 2, 20);
        assert_eq!(returned, vec![2], "new handle rejected, not the resident one");
        assert_eq!(m.peek(0), Some(&1), "in-flight entry survives untouched");
        assert_eq!(m.resident_bytes(), 10);
        assert_eq!(m.stats().evictions, 0);
        m.unpin(0);
        m.release_all();
    }

    #[test]
    fn budget_property_never_exceeded() {
        // After every install: within budget, unless only pinned entries
        // remain (eviction refuses to touch in-flight clusters).
        prop_check(150, |rng| {
            let policy = CachePolicy::new(rng.range(50, 400), rng.range(1, 5));
            let mut m: KvCacheManager<u64> = KvCacheManager::new(policy);
            let mut next = 0u64;
            for _ in 0..rng.range(1, 30) {
                let cid = rng.below(6);
                if m.contains(cid) {
                    m.unpin(cid);
                    continue;
                }
                let h = next;
                next += 1;
                m.install(cid, h, rng.range(1, 120));
                // the invariant holds at install time (eviction only runs
                // there): within budget, or nothing evictable remains.
                // It must be checked BEFORE the coin-flip unpin below —
                // unpinning never triggers eviction, so an over-budget
                // pinned admission legitimately stays over once unpinned,
                // until the next install reclaims it.
                let all_pinned =
                    m.resident_clusters().iter().all(|&c| m.is_pinned(c));
                assert!(
                    (m.resident_bytes() <= policy.max_bytes
                        && m.len() <= policy.max_entries)
                        || all_pinned,
                    "over budget with evictable entries: {} bytes / {} entries",
                    m.resident_bytes(),
                    m.len()
                );
                if rng.below(2) == 0 {
                    m.unpin(cid);
                }
            }
            m.release_all();
        });
    }

    #[test]
    fn pinned_never_evicted_property() {
        prop_check(150, |rng| {
            let policy = CachePolicy::new(rng.range(50, 300), rng.range(1, 4));
            let mut m: KvCacheManager<u64> = KvCacheManager::new(policy);
            let mut pinned: Vec<usize> = Vec::new(); // model of in-flight ids
            let mut next = 0u64;
            for _ in 0..rng.range(1, 40) {
                match rng.below(3) {
                    0 => {
                        let cid = rng.below(8);
                        if !m.contains(cid) {
                            let h = next;
                            next += 1;
                            m.install(cid, h, rng.range(1, 100));
                            pinned.push(cid);
                        }
                    }
                    1 => {
                        if !pinned.is_empty() {
                            let i = rng.below(pinned.len());
                            let cid = pinned.swap_remove(i);
                            assert!(m.unpin(cid));
                        }
                    }
                    _ => {
                        let _ = m.lookup(rng.below(8));
                    }
                }
                for &cid in &pinned {
                    assert!(m.contains(cid), "pinned cluster {cid} was evicted");
                    assert!(m.is_pinned(cid));
                }
            }
            m.release_all();
        });
    }

    #[test]
    fn every_handle_returned_exactly_once_property() {
        // Mirrors the seed's at_most_one_resident_property at multi-resident
        // scale: handles installed minus handles returned == handles resident,
        // and nothing is returned twice.
        prop_check(150, |rng| {
            let policy = CachePolicy::new(rng.range(20, 200), rng.range(1, 4));
            let mut m: KvCacheManager<u64> = KvCacheManager::new(policy);
            let mut live: Vec<u64> = Vec::new(); // handles we must get back
            let mut returned: Vec<u64> = Vec::new();
            let take = |hs: Vec<u64>, live: &mut Vec<u64>, ret: &mut Vec<u64>| {
                for h in hs {
                    assert!(live.contains(&h), "returned unknown handle {h}");
                    assert!(!ret.contains(&h), "handle {h} returned twice");
                    live.retain(|&x| x != h);
                    ret.push(h);
                }
            };
            let mut next = 0u64;
            for _ in 0..rng.range(1, 40) {
                match rng.below(5) {
                    0 | 1 => {
                        let cid = rng.below(6);
                        if !m.contains(cid) {
                            let h = next;
                            next += 1;
                            live.push(h);
                            let evicted = m.install(cid, h, rng.range(1, 80));
                            take(evicted, &mut live, &mut returned);
                            m.unpin(cid);
                        }
                    }
                    2 => {
                        let _ = m.lookup(rng.below(6));
                    }
                    3 => {
                        if let Some(h) = m.release(rng.below(6)) {
                            take(vec![h], &mut live, &mut returned);
                        }
                    }
                    _ => {
                        let drained = m.release_all();
                        take(drained, &mut live, &mut returned);
                    }
                }
                assert_eq!(live.len(), m.len(), "live model diverged from cache");
            }
            let drained = m.release_all();
            take(drained, &mut live, &mut returned);
            assert!(live.is_empty(), "leaked handles: {live:?}");
            assert_eq!(m.stats().resident_bytes, 0);
            assert_eq!(m.stats().released as usize, returned.len());
        });
    }

    #[test]
    fn nested_pins_cover_overlapping_tickets() {
        // Two in-flight tickets on the same cluster (e.g. a warm hit's
        // extend submitted while the install pin is still held) must stack:
        // the entry survives budget pressure until the LAST ticket unpins.
        let mut m: KvCacheManager<u32> = KvCacheManager::new(CachePolicy::new(usize::MAX, 1));
        m.install(0, 10, 1); // ticket 1 (install pin)
        assert_eq!(m.pin_count(0), 1);
        assert!(m.pin(0)); // ticket 2
        assert_eq!(m.pin_count(0), 2);
        m.unpin(0); // ticket 1 completes
        assert_eq!(m.pin_count(0), 1);
        let evicted = m.install(1, 11, 1); // budget pressure: still pinned
        assert!(evicted.is_empty(), "cluster with a live ticket must survive");
        assert!(m.contains(0));
        m.unpin(0); // ticket 2 completes
        assert_eq!(m.pin_count(0), 0);
        let evicted = m.install(2, 12, 1);
        assert_eq!(evicted, vec![10], "unpinned entry finally reclaimable");
        assert_eq!(m.pin_count(99), 0, "absent cluster has no pins");
        m.unpin(1);
        m.unpin(2);
        m.release_all();
    }

    #[test]
    fn stats_peak_monotone() {
        let mut m: KvCacheManager<()> = unbounded();
        m.install(0, (), 100);
        m.unpin(0);
        m.release(0);
        m.install(1, (), 50);
        assert_eq!(m.stats().peak_bytes, 100);
        assert_eq!(m.stats().resident_bytes, 50);
        m.unpin(1);
        m.release(1);
    }
}
