//! End-to-end pipeline benchmarks — one group per paper table shape:
//! Table 2 (baseline vs +SubGCache per-query cost), Table 3 (linkage),
//! Table 4 / Fig. 3 (batch & cluster scaling). Uses small batches; the
//! table binaries produce the full-protocol numbers.

use subgcache::cluster::Linkage;
use subgcache::coordinator::{Coordinator, ServeConfig};
use subgcache::prelude::*;
use subgcache::runtime::{ArtifactStore, Engine};
use subgcache::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::discover()?;
    let engine = Engine::start(&store)?;
    let ds = store.dataset("scene_graph")?;
    let queries = ds.sample_test(12, 7);
    let retriever = GRetriever::default();

    let mut b = Bench::quick();

    println!("== bench_table2_e2e: per-batch serving cost (12 queries) ==");
    let coord = Coordinator::new(&store, &engine,
                                 ServeConfig { n_clusters: 1, ..Default::default() })?;
    coord.serve_baseline(&ds, &queries, &retriever)?; // warm compile
    b.run("baseline: 12-query batch", || {
        coord.serve_baseline(&ds, &queries, &retriever).unwrap();
    });
    b.run("subgcache: 12-query batch (c=1)", || {
        coord.serve_subgcache(&ds, &queries, &retriever).unwrap();
    });

    println!("\n== bench_table3_linkage: cluster stage per linkage ==");
    for linkage in Linkage::ALL {
        let coord = Coordinator::new(&store, &engine, ServeConfig {
            n_clusters: 3, linkage, ..Default::default()
        })?;
        b.run(&format!("subgcache c=3 linkage={}", linkage.name()), || {
            coord.serve_subgcache(&ds, &queries, &retriever).unwrap();
        });
    }

    println!("\n== bench_table4_scaling / bench_fig3_sweep: batch & c scaling ==");
    for &n in &[4usize, 8, 16] {
        let qs = ds.sample_test(n, 7);
        let coord = Coordinator::new(&store, &engine,
                                     ServeConfig { n_clusters: 2, ..Default::default() })?;
        b.run(&format!("subgcache batch={n} (c=2)"), || {
            coord.serve_subgcache(&ds, &qs, &retriever).unwrap();
        });
    }
    for &c in &[1usize, 4, 12] {
        let coord = Coordinator::new(&store, &engine,
                                     ServeConfig { n_clusters: c, ..Default::default() })?;
        b.run(&format!("subgcache c={c} (batch=12)"), || {
            coord.serve_subgcache(&ds, &queries, &retriever).unwrap();
        });
    }
    Ok(())
}
