//! Engine hot-path benchmarks: device-resident KV (the zero-copy
//! prefill→extend handoff) and pipelined submit/wait, with a JSON emitter
//! (`BENCH_engine.json`) so the wins are tracked run over run.
//!
//! Two modes:
//! * **full** (artifacts present): device benchmarks of prefill / extend /
//!   handoff / TTFT on the default engine vs a forced host-bounce engine
//!   (`SUBGCACHE_KV_HOST_BOUNCE=1` — the seed's device→host→device KV
//!   path), plus a serial-vs-pipelined two-query comparison, plus the host
//!   cases below. The `*_host_kv_bytes` fields in the JSON record how many
//!   KV bytes each engine moved through the host (0 is the zero-copy
//!   target).
//! * **host-only** (artifacts absent, e.g. the CI smoke step): only the
//!   engine-free cases, in `Bench::quick()` budgets — the perf surface
//!   still compiles, runs, and emits JSON on a fresh clone.

use subgcache::cache::{CachePolicy, KvCacheManager};
use subgcache::coordinator::argmax;
use subgcache::graph::{Edge, Node, Subgraph, TextualGraph};
use subgcache::retrieval::GraphFeatures;
use subgcache::runtime::{pack_subgraph, ArtifactStore, BatchConfig, Engine};
use subgcache::util::bench::{emit_bench_json, Bench, JsonRow};

use std::time::Duration;

const BACKBONE: &str = "llama-3.2-3b-sim";

/// Small synthetic chain graph so the host-side cases need no artifacts.
fn synth_graph(n: usize) -> TextualGraph {
    let nodes = (0..n)
        .map(|i| Node {
            id: i,
            name: format!("n{i}"),
            text: format!("node {i} with attribute {}", i * 7 % 13),
        })
        .collect();
    let edges = (0..n.saturating_sub(1))
        .map(|i| Edge { src: i, dst: i + 1, text: "linked to".into() })
        .collect();
    TextualGraph::new("synthetic", nodes, edges).expect("chain graph is valid")
}

/// Engine-free cases: the host work that pipelining hides in device shadows.
fn host_side_cases(b: &mut Bench) {
    b.run("host: cache install+lookup+evict churn (64 clusters)", || {
        let mut m: KvCacheManager<u64> = KvCacheManager::new(CachePolicy::new(1 << 20, 8));
        for cid in 0..64usize {
            if !m.lookup(cid).is_hit() {
                let _ = m.install(cid, cid as u64, 96 * 1024);
            }
            m.unpin(cid);
            // warm-path probe: a hit pins, a miss reserves — both resolved
            // immediately (the serving discipline in miniature).
            if m.lookup(cid % 8).is_hit() {
                m.unpin(cid % 8);
            } else {
                m.abort_install(cid % 8);
            }
        }
        let _ = m.release_all();
    });

    let row: Vec<f32> = (0..4096)
        .map(|i: u64| ((i.wrapping_mul(2654435761)) % 9973) as f32 * 1e-3)
        .collect();
    b.run("host: argmax over 4096-logit row", || {
        std::hint::black_box(argmax(std::hint::black_box(&row)));
    });

    let g = synth_graph(64);
    let feats = GraphFeatures::build(&g);
    let sg = Subgraph::from_parts(0..16, 0..12);
    let dim = feats.dim();
    b.run("host: pack_subgraph (N=64)", || {
        std::hint::black_box(pack_subgraph(&g, &feats, &sg, 64, dim));
    });
}

/// Stand-in for per-query host prompt prep (retrieve + verbalize +
/// tokenize) in the serial-vs-pipelined comparison.
fn host_prep() {
    let mut acc = 0u64;
    for i in 0..200_000u64 {
        acc = acc.wrapping_add(i ^ (acc >> 3));
    }
    std::hint::black_box(acc);
}

/// Device cases; returns extra (key, numeric-value) pairs for the JSON.
fn full_cases(b: &mut Bench, store: &ArtifactStore)
              -> anyhow::Result<Vec<(String, String)>> {
    let c = *store.constants();
    // the env flag is read once per Engine start, so two engines started
    // with the flag flipped give both KV paths in one process.
    std::env::remove_var("SUBGCACHE_KV_HOST_BOUNCE");
    let fast = Engine::start(store)?;
    std::env::set_var("SUBGCACHE_KV_HOST_BOUNCE", "1");
    let slow = Engine::start(store)?;
    std::env::remove_var("SUBGCACHE_KV_HOST_BOUNCE");
    fast.warmup(BACKBONE)?;
    slow.warmup(BACKBONE)?;

    let mut tokens = vec![c.pad_id; c.max_seq];
    tokens[0] = c.bos_id;
    for (i, t) in tokens.iter_mut().enumerate().take(400).skip(1) {
        *t = 4 + (i as i32 % 200);
    }
    let mut q = vec![c.pad_id; c.max_q];
    for (i, t) in q.iter_mut().enumerate().take(12) {
        *t = 4 + i as i32;
    }
    let qlen = 12i32;

    for (name, engine) in [("device-resident", &fast), ("host-bounce", &slow)] {
        let (kv, _) = engine.prefill(BACKBONE, &tokens, 400)?;
        b.run(&format!("prefill 400 tokens [{name}]"), || {
            let (h, _) = engine.prefill(BACKBONE, &tokens, 400).unwrap();
            engine.release(h);
        });
        b.run(&format!("extend Q={} [{name}]", c.max_q), || {
            let (h, _) = engine.extend(BACKBONE, &kv, 400, &q, qlen).unwrap();
            engine.release(h);
        });
        b.run(&format!("prefill->extend handoff [{name}]"), || {
            let (h, _) = engine.prefill(BACKBONE, &tokens, 400).unwrap();
            let (h2, _) = engine.extend(BACKBONE, &h, 400, &q, qlen).unwrap();
            engine.release(h2);
            engine.release(h);
        });
        // TTFT core: prompt-ready -> first token over a cold prefix
        // (prefill + extend + argmax over the returned [V] row).
        b.run(&format!("ttft prefix+question [{name}]"), || {
            let (h, _) = engine.prefill(BACKBONE, &tokens, 400).unwrap();
            let (h2, row) = engine.extend(BACKBONE, &h, 400, &q, qlen).unwrap();
            std::hint::black_box(argmax(&row));
            engine.release(h2);
            engine.release(h);
        });
        engine.release(kv);
    }

    // pipelined vs serial submission: the same two-query workload, with the
    // second query's host prep either serialized or ridden in the first
    // prefill's shadow via submit/wait.
    b.run("2 queries serial (prep then prefill, twice)", || {
        for _ in 0..2 {
            host_prep();
            let (h, _) = fast.prefill(BACKBONE, &tokens, 400).unwrap();
            fast.release(h);
        }
    });
    b.run("2 queries pipelined (next prep in prefill shadow)", || {
        host_prep(); // the opening query's prep has no shadow to ride
        let pending = fast.submit_prefill(BACKBONE, &tokens, 400).unwrap();
        host_prep(); // second query's prep overlaps the first prefill
        let (h, _) = pending.wait().unwrap();
        fast.release(h);
        let pending = fast.submit_prefill(BACKBONE, &tokens, 400).unwrap();
        let (h, _) = pending.wait().unwrap();
        fast.release(h);
    });

    // fused-batch cases: 4 concurrent submissions ride one lane launch
    // (a fused device call when the module ships a `prefill_batch4` HLO
    // entry, a counted per-member fallback loop otherwise). The `batch=<n>`
    // tag in the row name is what `SimLatency::from_bench_json` fits the
    // per-item batch slope from, so these rows calibrate the sim's fusion
    // model against the real engine.
    let batched = Engine::start_with(store, BatchConfig::new(4, Duration::from_millis(2)))?;
    batched.warmup(BACKBONE)?;
    let (bkv, _) = batched.prefill(BACKBONE, &tokens, 400)?;
    b.run(&format!("extend Q={} batch=4 [fused]", c.max_q), || {
        let pending: Vec<_> = (0..4)
            .map(|_| batched.submit_extend(BACKBONE, &bkv, 400, &q, qlen).unwrap())
            .collect();
        for p in pending {
            let (h, _) = p.wait().unwrap();
            batched.release(h);
        }
    });
    b.run("prefill 400 tokens batch=4 [fused]", || {
        let pending: Vec<_> = (0..4)
            .map(|_| batched.submit_prefill(BACKBONE, &tokens, 400).unwrap())
            .collect();
        for p in pending {
            let (h, _) = p.wait().unwrap();
            batched.release(h);
        }
    });
    batched.release(bkv);

    let fs = fast.stats()?;
    let ss = slow.stats()?;
    let bs = batched.stats()?;
    println!(
        "\nhost KV bytes moved: device-resident {} vs host-bounce {}; \
         batched engine took {} unbatched fallbacks",
        fs.host_kv_bytes, ss.host_kv_bytes, bs.unbatched_fallbacks
    );
    Ok(vec![
        ("device_host_kv_bytes".into(), fs.host_kv_bytes.to_string()),
        ("bounce_host_kv_bytes".into(), ss.host_kv_bytes.to_string()),
        ("batched_unbatched_fallbacks".into(), bs.unbatched_fallbacks.to_string()),
    ])
}

fn main() -> anyhow::Result<()> {
    let artifacts = ArtifactStore::discover().ok();
    let quick = artifacts.is_none() || std::env::var("SUBGCACHE_BENCH_QUICK").is_ok();
    let mut b = if quick { Bench::quick() } else { Bench::default() };
    let mode = if artifacts.is_some() { "full" } else { "host-only" };
    println!("== engine hot path ({mode}) ==");

    host_side_cases(&mut b);
    let extra = match &artifacts {
        Some(store) => full_cases(&mut b, store)?,
        None => {
            println!("(artifacts/ absent: device cases skipped, quick budgets)");
            Vec::new()
        }
    };

    let rows: Vec<JsonRow> = b.results().iter().map(JsonRow::from).collect();
    emit_bench_json("BENCH_engine.json", "engine_hot_path", mode, &extra, &rows)?;
    println!("\nwrote BENCH_engine.json ({} cases)", b.results().len());
    Ok(())
}
