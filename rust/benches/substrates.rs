//! Substrate micro-benchmarks: the non-LLM stages on the request path —
//! retrieval (PCST vs ego), clustering per linkage and batch size,
//! representative merge, verbalization + tokenization.

use subgcache::cluster::{cluster, Linkage};
use subgcache::graph::{prefix_text, Subgraph};
use subgcache::prelude::*;
use subgcache::runtime::ArtifactStore;
use subgcache::util::bench::Bench;
use subgcache::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::discover()?;
    let scene = store.dataset("scene_graph")?;
    let oag = store.dataset("oag")?;
    let tok = store.tokenizer();
    let mut b = Bench::quick();

    println!("== retrieval ==");
    for (ds, name) in [(&scene, "scene_graph"), (&oag, "oag")] {
        let feats = GraphFeatures::build(&ds.graph);
        let q = &ds.queries[0].text;
        let gr = GRetriever::default();
        let grag = GragRetriever::default();
        b.run(&format!("g-retriever (PCST) on {name}"), || {
            std::hint::black_box(gr.retrieve(&ds.graph, &feats, q));
        });
        b.run(&format!("grag (2-hop ego) on {name}"), || {
            std::hint::black_box(grag.retrieve(&ds.graph, &feats, q));
        });
    }

    println!("\n== clustering (64-dim embeddings) ==");
    let mut rng = Rng::new(3);
    for &m in &[50usize, 100, 200] {
        let embs: Vec<Vec<f32>> = (0..m)
            .map(|_| (0..64).map(|_| rng.normal() as f32).collect())
            .collect();
        b.run(&format!("ward m={m} c=2"), || {
            std::hint::black_box(cluster(&embs, 2, Linkage::Ward));
        });
    }
    let embs: Vec<Vec<f32>> = (0..100)
        .map(|_| (0..64).map(|_| rng.normal() as f32).collect())
        .collect();
    for linkage in Linkage::ALL {
        b.run(&format!("{} m=100 c=5", linkage.name()), || {
            std::hint::black_box(cluster(&embs, 5, linkage));
        });
    }

    println!("\n== representative merge + verbalize + tokenize ==");
    let feats = GraphFeatures::build(&scene.graph);
    let gr = GRetriever::default();
    let subs: Vec<Subgraph> = scene.queries.iter().take(50)
        .map(|q| gr.retrieve(&scene.graph, &feats, &q.text)).collect();
    let refs: Vec<&Subgraph> = subs.iter().collect();
    b.run("representative merge (50 subgraphs)", || {
        std::hint::black_box(Subgraph::representative(&refs));
    });
    let rep = Subgraph::representative(&refs);
    b.run("verbalize representative (budget 704)", || {
        std::hint::black_box(prefix_text(&scene.graph, &rep, Some(704)));
    });
    let text = prefix_text(&scene.graph, &rep, Some(704));
    b.run("tokenize representative prompt", || {
        std::hint::black_box(tok.encode(&text));
    });
    Ok(())
}
