//! Serving-workload bench: the table 4 (in-batch sweep) and table 5
//! (online streaming) wall/qps summaries as a tracked JSON artifact
//! (`BENCH_serving.json`, same shape as `BENCH_engine.json`) so every PR
//! can be compared on the same serving workloads.
//!
//! Two modes:
//! * **artifacts** (present): the real PJRT engine on the real datasets,
//!   modest batch sizes.
//! * **sim-quick** (fresh clone / CI smoke): the deterministic
//!   [`SimBackend`] over the in-memory world with millisecond virtual
//!   latencies — the scheduler, lanes and emitter are exercised end to end
//!   without `make artifacts`, and the depth sweep shows the k=1 vs k≥2
//!   pipeline difference in the JSON.
//!
//! Both modes also run a `--streams N` (default 4) multi-stream case: N
//! replicated query streams served concurrently over ONE shared KV-cache
//! pool, emitting the pool-level dedup row (`pool_prefills`,
//! `shared_hits`, `dedup_bytes_saved`, lock contention) next to the serial
//! rows — the cross-stream sharing regression surface.
//!
//! `--host-cache-bytes N` (via the shared cache flags) threads a host KV
//! tier through every online cell; in sim-quick mode it additionally runs
//! a single-entry-device-budget cell whose row must show nonzero
//! `demotions`/`promotions`/`host_hits` — the tier regression surface.
//! `--disk-cache-bytes N` does the same for the disk archive tier: a
//! squeezed-host cell whose row must show nonzero
//! `archived`/`recalls`/`disk_hits` (CI emits it as
//! `BENCH_serving_disk.json`).
//!
//! `--fault-seed N --transient-prob P --spike-prob P --spike-ms MS` arm the
//! sim's chaos plan and stamp every emitted row with the injection config,
//! so faulty rows can never masquerade as clean ones. Sim-quick mode always
//! finishes with an `online sim overload flash-crowd` cell — bounded
//! (blocking) lane queues, an armed circuit breaker, a seeded flash crowd
//! and the admission/brownout ladder enabled — whose
//! `shed*`/`brownout_*`/`llm_queue_depth_*` fields are the overload-plane
//! regression surface.

use subgcache::harness::{batch_config_from_args, cache_policy_from_args,
                         fault_flags_present, fault_plan_from_args,
                         multi_serving_row, run_cell_with,
                         run_multi_online_cell_with, run_online_cell_with, Cell,
                         ServingBench};
use subgcache::prelude::*;
use subgcache::runtime::{SimBackend, SIM_BACKBONE};

const OUT: &str = "BENCH_serving.json";

fn artifact_mode(store: &ArtifactStore, streams: usize, batch_cfg: BatchConfig,
                 cache: CachePolicy, faults: Option<&FaultPlan>)
                 -> anyhow::Result<ServingBench> {
    let mut bench = ServingBench::new("artifacts");
    bench.set_batch(batch_cfg);
    if let Some(p) = faults {
        // the PJRT engine has no injection hooks — the stamp records that
        // the flags were given, so the row provenance stays honest.
        println!("note: fault flags are recorded on rows but the PJRT engine \
                  does not inject faults");
        bench.set_faults(p);
    }
    let engine = Engine::start_with(store, batch_cfg)?;
    let backbone = "llama-3.2-3b-sim";
    for dataset in ["scene_graph", "oag"] {
        let ds = store.dataset(dataset)?;
        for &batch in &[25usize, 50] {
            let cell = Cell::new(dataset, "g-retriever", backbone, batch);
            let r = run_cell_with(store, &engine, &ds, &cell)?;
            println!("batch {dataset} b={batch}: subgcache {:.2}s wall",
                     r.subgcache.metrics.wall_time);
            bench.push(&format!("batch {dataset} b={batch} baseline"), &r.baseline);
            bench.push(&format!("batch {dataset} b={batch} subgcache"), &r.subgcache);
        }
        for depth in [1usize, 2] {
            let mut cell = Cell::new(dataset, "g-retriever", backbone, 50);
            cell.pipeline_depth = depth;
            cell.cache = cache;
            let r = run_online_cell_with(store, &engine, &ds, &cell)?;
            println!("online {dataset} k={depth}: {:.2}s wall ({:.1} q/s)",
                     r.online.metrics.wall_time, r.online.metrics.qps());
            bench.push(&format!("online {dataset} k={depth}"), &r.online);
        }
        let mut cell = Cell::new(dataset, "g-retriever", backbone, 25);
        cell.cache = cache;
        let mr = run_multi_online_cell_with(store, &engine, &ds, &cell, streams)?;
        println!("online {dataset} streams={streams}: {:.2}s wall ({:.1} q/s, \
                  {} shared hits)",
                 mr.multi.wall_time, mr.multi.qps(), mr.multi.shared_hits());
        bench.push_row(multi_serving_row(
            &format!("online {dataset} streams={streams}"), &mr.multi));
    }
    Ok(bench)
}

fn sim_quick_mode(streams: usize, batch_cfg: BatchConfig, cache: CachePolicy,
                  faults: Option<&FaultPlan>) -> anyhow::Result<ServingBench> {
    let mut bench = ServingBench::new("sim-quick");
    bench.set_batch(batch_cfg);
    if let Some(p) = faults {
        bench.set_faults(p);
    }
    let plan = faults.cloned().unwrap_or_default();
    let store = sim_store();
    let ds = sim_dataset(4, 4);
    // virtual latencies with encode ≈ prefill, the regime where the lane
    // split and depth-k scheduler show their overlap in the numbers. The
    // per-item slopes are sub-linear (fused calls cost base + slope·(n−1))
    // so a `--max-batch > 1` run shows its win in the same JSON.
    let lat = SimLatency::from_millis(6, 2, 2, 6).with_per_item_millis(2, 1, 1, 6);
    let sim = SimBackend::start_faulty(&store, lat, batch_cfg, plan.clone(),
                                       SupervisorPolicy::default())?;
    for &batch in &[8usize, 16] {
        let cell = Cell::new("sim", "g-retriever", SIM_BACKBONE, batch);
        let r = run_cell_with(&store, &sim, &ds, &cell)?;
        println!("batch sim b={batch}: subgcache {:.3}s wall",
                 r.subgcache.metrics.wall_time);
        bench.push(&format!("batch sim b={batch} baseline"), &r.baseline);
        bench.push(&format!("batch sim b={batch} subgcache"), &r.subgcache);
    }
    for depth in [1usize, 2, 4] {
        let mut cell = Cell::new("sim", "g-retriever", SIM_BACKBONE, 12);
        cell.pipeline_depth = depth;
        cell.cache = cache;
        cell.online_threshold = f32::INFINITY;
        let r = run_online_cell_with(&store, &sim, &ds, &cell)?;
        println!("online sim k={depth}: {:.3}s wall ({:.1} q/s, {:.1} ms overlapped)",
                 r.online.metrics.wall_time, r.online.metrics.qps(),
                 r.online.metrics.overlap_time * 1e3);
        bench.push(&format!("online sim k={depth}"), &r.online);
    }
    // cross-stream sharing smoke: N replicated streams, one shared pool.
    // Prefill dominates, so the dedup (one pool prefill per distinct
    // representative instead of N) is visible in the wall/qps row.
    let mut cell = Cell::new("sim", "g-retriever", SIM_BACKBONE, 12);
    cell.cache = cache;
    let mr = run_multi_online_cell_with(&store, &sim, &ds, &cell, streams)?;
    println!("online sim streams={streams}: {:.3}s wall ({:.1} q/s), \
              {} pool prefills, {} shared hits, lock {}/{} contended",
             mr.multi.wall_time, mr.multi.qps(), mr.multi.shared.prefills,
             mr.multi.shared_hits(), mr.multi.lock.contended,
             mr.multi.lock.acquisitions);
    bench.push_row(multi_serving_row(
        &format!("online sim streams={streams}"), &mr.multi));
    // host-tier smoke (`--host-cache-bytes`): one stream under a
    // single-entry device budget, so cluster churn demotes representatives
    // to the host tier and revisits promote them back — the
    // demotions/promotions/host_hits counters in the emitted row are the
    // regression surface. Copies are given a real per-byte cost so the
    // promoted path's latency is visible, not free.
    if cache.host_bytes > 0 {
        let lat_tier = SimLatency::from_millis(6, 2, 2, 6)
            .with_host_copy_per_byte(std::time::Duration::from_nanos(15));
        let sim_tier = SimBackend::start_with(&store, lat_tier, batch_cfg)?;
        let mut cell = Cell::new("sim", "g-retriever", SIM_BACKBONE, 12);
        cell.cache = CachePolicy { max_entries: 1, ..cache };
        let r = run_online_cell_with(&store, &sim_tier, &ds, &cell)?;
        println!("online sim host-tier: {:.3}s wall, {} demotions, \
                  {} promotions, {} host hits",
                 r.online.metrics.wall_time, r.online.cache.demotions,
                 r.online.cache.promotions, r.online.cache.host_hits);
        bench.push("online sim host-tier", &r.online);
    }
    // disk-tier smoke (`--disk-cache-bytes`): same single-entry device
    // budget, but with a host budget squeezed down to one demoted copy so
    // churn pushes colder representatives off the host tier and into the
    // disk archive; revisits then recall them disk → host → device. The
    // archived/recalls/disk_hits counters in the emitted row are the
    // regression surface.
    if cache.disk_bytes > 0 {
        let lat_tier = SimLatency::from_millis(6, 2, 2, 6)
            .with_host_copy_per_byte(std::time::Duration::from_nanos(15));
        let sim_tier = SimBackend::start_with(&store, lat_tier, batch_cfg)?;
        let mut cell = Cell::new("sim", "g-retriever", SIM_BACKBONE, 12);
        cell.cache = CachePolicy {
            max_entries: 1,
            host_bytes: cache.host_bytes.clamp(1, 4096),
            ..cache
        };
        let r = run_online_cell_with(&store, &sim_tier, &ds, &cell)?;
        println!("online sim disk-tier: {:.3}s wall, {} archived, \
                  {} recalls, {} disk hits",
                 r.online.metrics.wall_time, r.online.cache.archived,
                 r.online.cache.recalls, r.online.cache.disk_hits);
        bench.push("online sim disk-tier", &r.online);
    }
    // overload smoke: a seeded flash crowd oversubscribes the LLM lane of a
    // sim with bounded (blocking) lane queues, an armed circuit breaker, a
    // deadline and the brownout ladder enabled — the row's
    // shed/brownout/queue-depth fields are the overload-plane regression
    // surface CI's finite-stats guard walks.
    {
        let sim_over = SimBackend::start_guarded(
            &store, lat, batch_cfg, plan, SupervisorPolicy::default(),
            QueueConfig::block(8, std::time::Duration::from_millis(200)),
            Some(BreakerConfig::default()))?;
        let mut cell = Cell::new("sim", "g-retriever", SIM_BACKBONE, 16);
        cell.cache = cache;
        cell.online_threshold = f32::INFINITY;
        cell.deadline = Some(std::time::Duration::from_millis(60));
        cell.overload = OverloadConfig {
            arrivals: ArrivalPlan {
                seed: 42,
                process: ArrivalProcess::FlashCrowd {
                    mean: std::time::Duration::from_millis(12),
                    at: 4,
                    size: 8,
                },
                zipf_skew: 1.2,
            },
            shed: true,
            initial_estimate: std::time::Duration::from_secs_f64(lat.serial_sum()),
            headroom: 1.0,
            brownout: Some(BrownoutConfig {
                backlog_steps: [
                    std::time::Duration::from_millis(10),
                    std::time::Duration::from_millis(25),
                    std::time::Duration::from_millis(40),
                ],
                depth_watermark: None,
                p95_watermark: None,
                gen_cap: 8,
            }),
        };
        let r = run_online_cell_with(&store, &sim_over, &ds, &cell)?;
        let sh = &r.online.metrics.reliability.shed;
        println!("online sim overload flash-crowd: {:.3}s wall, {} admitted, \
                  {} shed ({} deadline / {} overloaded / {} brownout), \
                  {} brownout spans",
                 r.online.metrics.wall_time, sh.admitted, sh.total_shed(),
                 sh.shed_deadline, sh.shed_overloaded, sh.shed_brownout,
                 r.online.metrics.reliability.brownout_spans);
        bench.push("online sim overload flash-crowd", &r.online);
    }
    Ok(bench)
}

fn main() -> anyhow::Result<()> {
    // cargo passes `--bench` through; `--streams N` picks the multi-stream
    // fan-out (CI runs `cargo bench --bench serving -- --streams 4`).
    // `--streams 1` is honored: a one-stream-over-shared-pool row is the
    // parity reference the concurrency suite compares against.
    // `--max-batch N --batch-window MS` turn on the LLM-lane micro-batcher
    // (default off), and `--out PATH` redirects the JSON so batched and
    // unbatched runs can sit side by side as artifacts.
    let args = Args::from_env();
    let streams = args.usize_or("streams", 4).max(1);
    let batch_cfg = batch_config_from_args(&args)?;
    let cache = cache_policy_from_args(&args)?;
    // `--fault-seed/--transient-prob/--spike-prob/--spike-ms` drive the sim
    // chaos plan and stamp every emitted row with the injection config.
    let fault_plan = fault_plan_from_args(&args)?;
    let faults = fault_flags_present(&args).then_some(&fault_plan);
    let out = args.get_or("out", OUT).to_string();
    let artifacts = ArtifactStore::discover().ok();
    let mode = if artifacts.is_some() { "artifacts" } else { "sim-quick" };
    println!("== serving bench ({mode}, streams = {streams}, max_batch = {}, \
              window = {:.1} ms, host_cache = {} B, disk_cache = {} B, \
              fault_seed = {}) ==",
             batch_cfg.max_batch, batch_cfg.max_wait.as_secs_f64() * 1e3,
             cache.host_bytes, cache.disk_bytes, fault_plan.seed);
    let bench = match &artifacts {
        Some(store) => artifact_mode(store, streams, batch_cfg, cache, faults)?,
        None => sim_quick_mode(streams, batch_cfg, cache, faults)?,
    };
    bench.emit(&out)?;
    println!("\nwrote {out} ({} rows)", bench.len());
    Ok(())
}
