//! Engine-level micro-benchmarks: the FLOP asymmetry behind every paper
//! table — full prefill (S=768) vs query extend (Q=32) vs scan-decode — plus
//! GNN encode. Run with `cargo bench --offline`.

use subgcache::retrieval::GraphFeatures;
use subgcache::runtime::{pack_subgraph, ArtifactStore, Engine};
use subgcache::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::discover()?;
    let engine = Engine::start(&store)?;
    let c = *store.constants();
    let backbone = "llama-3.2-3b-sim";
    engine.warmup(backbone)?;
    engine.warmup("graph_transformer")?;
    engine.warmup("gat")?;

    let mut tokens = vec![c.pad_id; c.max_seq];
    tokens[0] = c.bos_id;
    for (i, t) in tokens.iter_mut().enumerate().take(400).skip(1) {
        *t = 4 + (i as i32 % 200);
    }
    let q = {
        let mut q = vec![c.pad_id; c.max_q];
        for (i, t) in q.iter_mut().enumerate().take(12) {
            *t = 4 + i as i32;
        }
        q
    };
    let (kv, _) = engine.prefill(backbone, &tokens, 400)?;

    let mut b = Bench::default();
    println!("== engine ops ({backbone}, S={}, Q={}, G={}) ==",
             c.max_seq, c.max_q, c.max_gen);
    b.run("prefill full prompt (400 real tokens)", || {
        let (h, _) = engine.prefill(backbone, &tokens, 400).unwrap();
        engine.release(h);
    });
    b.run("prefill short prompt (64 real tokens)", || {
        let (h, _) = engine.prefill(backbone, &tokens, 64).unwrap();
        engine.release(h);
    });
    b.run("extend query against cached prefix (Q=32)", || {
        let (h, _) = engine.extend(backbone, &kv, 400, &q, 12).unwrap();
        engine.release(h);
    });
    b.run("generate 32 tokens (in-HLO scan decode)", || {
        engine.generate(backbone, &kv, 412, 5).unwrap();
    });

    let ds = store.dataset("scene_graph")?;
    let feats = GraphFeatures::build(&ds.graph);
    let sg = subgcache::graph::Subgraph::from_parts(0..12, 0..8);
    for gnn in ["graph_transformer", "gat"] {
        let p = pack_subgraph(&ds.graph, &feats, &sg, c.n_max, c.feat_dim);
        let (x, adj, mask) = (p.x, p.adj, p.mask);
        b.run(&format!("gnn encode ({gnn}, N={})", c.n_max), || {
            engine.encode(gnn, x.clone(), adj.clone(), mask.clone()).unwrap();
        });
    }
    engine.release(kv);

    let s = b.results();
    let ratio = s[0].median_ns / s[2].median_ns;
    println!("\nprefill/extend ratio: {ratio:.1}x \
              (the per-query PFTT saving SubGCache banks per cache hit)");
    Ok(())
}
